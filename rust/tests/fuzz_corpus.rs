//! Differential fuzz harness over the generated expression-kernel
//! corpus: every grammar-enumerated kernel must satisfy the engine's
//! bitwise-identity contracts on code nobody hand-wrote.
//!
//! Layer 1 — scalar vs block(/lanes): each kernel runs through the
//! slice call sites and through a scalar replay of every slice
//! kernel's documented op sequence; values, counters, and trace bytes
//! must be bit-identical under the full placement battery (exact,
//! WP-truncate, dynamic perturbation, CIP, FCS, target filters).
//! Layer 2 — serial vs parallel vs sharded: exploring a corpus kernel
//! must produce the same archive bit-for-bit regardless of the worker
//! pool shape.
//!
//! Any layer-1 divergence is shrunk to a minimal term and printed as a
//! re-runnable `neat corpus --term '<canonical>'` reproducer.

use std::collections::HashSet;
use std::path::PathBuf;
use std::time::Duration;

use neat::bench_suite::corpus::{self, CorpusKernel, Term, DEFAULT_LEN};
use neat::bench_suite::{self, Workload};
use neat::coordinator::experiments::{explore_rule_with, Budget};
use neat::coordinator::suite::{plan_shards, shard_map};
use neat::coordinator::{EvalDetail, EvalProblem, Evaluator, Executor, RuleKind};
use neat::fpi::FormatSpec;
use neat::service::{JobKind, JobSpec, JobState, Service, ServiceConfig};
use neat::tuner::{DescentStrategy, TuneGoal, Tuner, TunerConfig};

/// The CI corpus size (acceptance bar: >= 256 deduped kernels).
const CORPUS_SIZE: usize = 256;

fn corpus_terms() -> Vec<Term> {
    corpus::generate(CORPUS_SIZE, corpus::DEFAULT_SEED)
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("neat_fuzz_corpus_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// On a divergence, shrink to a minimal failing term and panic with a
/// reproducer the developer can paste straight into the CLI.
fn fail_with_reproducer(term: &Term, len: usize, err: &str) -> ! {
    let min = corpus::shrink(term, |t| corpus::identity_check(t, len).is_err());
    panic!(
        "identity divergence: {err}\n\
         minimal reproducer:\n  neat corpus --term '{}'",
        min.canonical()
    );
}

/// Acceptance bar: the fixed seed yields at least 256 kernels, twice
/// over identically, with no canonical-form duplicates, and the
/// grammar's sqrt terms and fused shapes actually show up.
#[test]
fn corpus_reaches_256_deduped_kernels_deterministically() {
    let a = corpus_terms();
    let b = corpus_terms();
    assert_eq!(a, b, "generation must be a pure function of the seed");
    assert!(a.len() >= CORPUS_SIZE, "only {} kernels generated", a.len());

    let canon: HashSet<String> = a.iter().map(|t| t.canonical()).collect();
    assert_eq!(canon.len(), a.len(), "canonical-form dedup failed");

    let with_sqrt = a.iter().filter(|t| t.contains_sqrt()).count();
    assert!(with_sqrt > 0, "sqrt terms must appear in the corpus");
    let heads = corpus::histogram(&a);
    assert!(
        heads.len() >= 6,
        "expected a diverse shape mix, got only {heads:?}"
    );
}

/// The tentpole assertion: scalar reference == block(/lanes) engine —
/// values, counters, and trace bytes — on every generated kernel.
#[test]
fn differential_identity_holds_on_every_generated_kernel() {
    let terms = corpus_terms();
    for term in &terms {
        if let Err(e) = corpus::identity_check(term, DEFAULT_LEN) {
            fail_with_reproducer(term, DEFAULT_LEN, &e);
        }
    }
}

/// A spread sample re-checked at the lane remainder edges for both
/// element widths (f32 lanes = 8, f64 lanes = 4): empty, singleton,
/// lane-1, lane, lane+1, ragged.
#[test]
fn boundary_lengths_hold_on_sampled_kernels() {
    let terms = corpus_terms();
    let picks = corpus::spread_indices(terms.len(), 12, corpus::DEFAULT_SEED);
    for &i in &picks {
        for len in [0usize, 1, 3, 4, 5, 7, 8, 9, 17] {
            if let Err(e) = corpus::identity_check(&terms[i], len) {
                fail_with_reproducer(&terms[i], len, &e);
            }
        }
    }
}

/// Satellite: every workload the content-addressed cache can see —
/// hand-ported registry plus the full generated corpus — carries a
/// distinct `(name, version())` pair, so no two workloads can ever
/// collide on a cache key.
#[test]
fn workload_name_version_pairs_are_unique_across_registry_and_corpus() {
    let mut pairs: Vec<(String, u32)> = bench_suite::all()
        .iter()
        .map(|w| (w.name().to_string(), w.version()))
        .collect();
    let terms = corpus_terms();
    for t in &terms {
        let k = CorpusKernel::new(t.clone());
        pairs.push((k.name().to_string(), k.version()));
    }
    let total = pairs.len();
    let unique: HashSet<&(String, u32)> = pairs.iter().collect();
    assert_eq!(unique.len(), total, "duplicate (name, version) pair");

    // the corpus versions are content hashes of the canonical term:
    // distinct terms must not collide across the whole corpus
    let versions: HashSet<u32> = terms.iter().map(|t| t.hash32()).collect();
    assert_eq!(versions.len(), terms.len(), "version hash collision");

    // and re-compiling the same term reproduces the same pair
    let k1 = CorpusKernel::new(terms[0].clone());
    let k2 = CorpusKernel::new(terms[0].clone());
    assert_eq!((k1.name(), k1.version()), (k2.name(), k2.version()));
}

/// Layer 2: exploring a corpus kernel yields bit-identical archives —
/// same genomes, same order, same `EvalDetail` bits — whether the
/// walk runs serial, on a worker pool, or sharded with nested
/// executors (the `neat suite` shape).
#[test]
fn serial_parallel_and_sharded_archives_are_bit_identical() {
    let terms = corpus_terms();
    let picks = corpus::spread_indices(terms.len(), 3, 0xA5);
    let names: Vec<String> =
        picks.iter().map(|&i| format!("corpus:{}", terms[i].canonical())).collect();

    let archive = |name: &str, exec: &Executor| -> Vec<(Vec<u32>, EvalDetail)> {
        let w = bench_suite::by_name(name).expect("corpus kernel resolves");
        let eval = Evaluator::new(w, None);
        explore_rule_with(&eval, RuleKind::Cip, Budget::quick(), exec).details
    };
    let assert_bitwise = |a: &[(Vec<u32>, EvalDetail)], b: &[(Vec<u32>, EvalDetail)]| {
        assert_eq!(a.len(), b.len());
        for ((ga, da), (gb, db)) in a.iter().zip(b) {
            assert_eq!(ga, gb, "genome order must match");
            assert_eq!(da.error.to_bits(), db.error.to_bits());
            assert_eq!(da.fpu_nec.to_bits(), db.fpu_nec.to_bits());
            assert_eq!(da.mem_nec.to_bits(), db.mem_nec.to_bits());
            assert_eq!(da.fpu_target_nec.to_bits(), db.fpu_target_nec.to_bits());
        }
    };

    let serial: Vec<_> = names.iter().map(|n| archive(n, &Executor::serial())).collect();
    for (n, s) in names.iter().zip(&serial) {
        let parallel = archive(n, &Executor::new(4));
        assert_bitwise(s, &parallel);
    }
    let sharded = shard_map(plan_shards(4, Some(2), names.len()), names.len(), |i, exec| {
        archive(&names[i], exec)
    });
    for (s, sh) in serial.iter().zip(&sharded) {
        assert_bitwise(s, sh);
    }
}

/// End-to-end: a generated kernel is tunable like any Table II row and
/// round-trips through a `neat serve` job submission, with the repeat
/// probe answered entirely from the content-addressed cache.
#[test]
fn corpus_kernel_tunes_and_round_trips_through_the_service() {
    let terms = corpus_terms();
    let term = &terms[corpus::spread_indices(terms.len(), 1, 7)[0]];
    let name = format!("corpus:{}", term.canonical());

    // heuristic tuner over the generated kernel
    let w = bench_suite::by_name(&name).expect("corpus kernel resolves");
    let eval = Evaluator::new(w, None);
    let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::new(2));
    let result = Tuner::new(TunerConfig {
        goal: TuneGoal::ErrorBudget(0.01),
        max_evals: 40,
        strategy: DescentStrategy::Lattice,
        exchange_rounds: 0,
        exchange_partners: 1,
    })
    .run(&problem);
    assert_eq!(result.genome.len(), eval.genome_len(RuleKind::Cip));
    assert!(result.probes_used > 0);
    assert!(result.objectives.error.is_finite());

    // service round trip: submit a probe, then resubmit the identical
    // configuration and require the cached fast path
    let mut cfg = ServiceConfig::new();
    cfg.threads = 2;
    cfg.cache_dir = Some(tmp("cache"));
    let service = Service::start(cfg).expect("service starts");
    let bits = term.width.mantissa_bits() / 2;
    let probe = || JobSpec {
        tenant: "fuzz".to_string(),
        priority: 1,
        target: None,
        formats: vec![],
        kind: JobKind::Probe {
            benchmark: name.clone(),
            rule: RuleKind::Wp,
            genome: vec![bits],
        },
    };
    let id = service.submit(probe()).expect("submit");
    let snap = service.wait(id, Duration::from_secs(120)).expect("probe finishes");
    assert_eq!(snap.state, JobState::Done, "error: {:?}", snap.error);
    let id2 = service.submit(probe()).expect("resubmit");
    let snap2 = service.wait(id2, Duration::from_secs(120)).expect("repeat finishes");
    assert_eq!(snap2.state, JobState::Done, "error: {:?}", snap2.error);
    assert!(snap2.cache_hit(), "repeat probe must be served from the cache");
    let _ = service.shutdown();
}

/// Format FPIs ride the same contracts as truncation on generated
/// code: exploring a corpus kernel over a custom-format menu (presets,
/// saturation, stochastic rounding) yields bit-identical archives on
/// the serial and pooled executors, and a probe pinned to a format
/// rung of the ladder round-trips through `neat serve` with the
/// repeat submission served from the content-addressed cache.
#[test]
fn format_menu_holds_identity_and_round_trips_on_corpus_kernels() {
    let terms = corpus_terms();
    let term = &terms[corpus::spread_indices(terms.len(), 1, 0x0F)[0]];
    let name = format!("corpus:{}", term.canonical());
    let menu = vec![
        FormatSpec::bfloat16(),
        FormatSpec::fp16().saturating(),
        FormatSpec::new(6, 6).stochastic(9),
    ];

    let archive = |exec: &Executor| {
        let w = bench_suite::by_name(&name).expect("corpus kernel resolves");
        let eval = Evaluator::with_formats(w, None, &menu);
        explore_rule_with(&eval, RuleKind::Wp, Budget::quick(), exec).details
    };
    let serial = archive(&Executor::serial());
    let pooled = archive(&Executor::new(4));
    assert_eq!(serial.len(), pooled.len());
    for ((ga, da), (gb, db)) in serial.iter().zip(&pooled) {
        assert_eq!(ga, gb, "genome order must match");
        assert_eq!(da.error.to_bits(), db.error.to_bits());
        assert_eq!(da.fpu_nec.to_bits(), db.fpu_nec.to_bits());
        assert_eq!(da.mem_nec.to_bits(), db.mem_nec.to_bits());
    }

    // a probe pinned to a format rung of the mixed gene ladder
    let w = bench_suite::by_name(&name).expect("corpus kernel resolves");
    let eval = Evaluator::with_formats(w, None, &menu);
    let fmt_gene = (1..=eval.max_gene())
        .find(|&g| eval.gene_name(g).starts_with("fmt["))
        .expect("menu contributes format rungs");

    let mut cfg = ServiceConfig::new();
    cfg.threads = 2;
    cfg.cache_dir = Some(tmp("format_cache"));
    let service = Service::start(cfg).expect("service starts");
    let probe = || JobSpec {
        tenant: "fuzz".to_string(),
        priority: 1,
        target: None,
        formats: menu.clone(),
        kind: JobKind::Probe {
            benchmark: name.clone(),
            rule: RuleKind::Wp,
            genome: vec![fmt_gene],
        },
    };
    let id = service.submit(probe()).expect("submit");
    let snap = service.wait(id, Duration::from_secs(120)).expect("probe finishes");
    assert_eq!(snap.state, JobState::Done, "error: {:?}", snap.error);
    let id2 = service.submit(probe()).expect("resubmit");
    let snap2 = service.wait(id2, Duration::from_secs(120)).expect("repeat finishes");
    assert_eq!(snap2.state, JobState::Done, "error: {:?}", snap2.error);
    assert!(snap2.cache_hit(), "repeat format probe must hit the cache");
    let _ = service.shutdown();
}
