//! Property-based tests over the system's core invariants, using the
//! vendored `proptest_lite` harness (the `proptest` crate is not in the
//! offline cache — see Cargo.toml).

use std::collections::HashMap;

use neat::engine::FpContext;
use neat::explore::nsga2::{non_dominated_sort, pareto_front, Nsga2, Nsga2Params};
use neat::explore::{Evaluated, FnProblem, Genome, Objectives};
use neat::fpi::{
    truncate_f32, truncate_f64, used_bits_f32, used_bits_f64, FpiLibrary, Precision,
};
use neat::placement::Placement;
use neat::stats::{lower_convex_hull, TradeoffPoint};
use neat::util::proptest_lite::{check, Config};
use neat::util::Pcg64;

fn cfg(cases: u64) -> Config {
    Config { cases, ..Default::default() }
}

// --- truncation semantics -------------------------------------------

#[test]
fn prop_truncation_never_increases_magnitude() {
    check(
        "truncate |.| non-increasing",
        cfg(2000),
        |rng| ((rng.normal() * 10f64.powi(rng.below(60) as i32 - 30)) as f32, rng.below(24) as u32 + 1),
        |&(x, k)| {
            let t = truncate_f32(x, k);
            t.abs() <= x.abs() && t.signum() == x.signum() || x == 0.0 || t == 0.0
        },
    );
}

#[test]
fn prop_truncation_idempotent_and_bounded() {
    check(
        "truncate idempotent, used_bits ≤ k",
        cfg(2000),
        |rng| (rng.normal() as f32 * 100.0, rng.below(24) as u32 + 1),
        |&(x, k)| {
            let t = truncate_f32(x, k);
            truncate_f32(t, k) == t && (t == 0.0 || used_bits_f32(t) <= k)
        },
    );
}

#[test]
fn prop_truncation_relative_error_bound() {
    check(
        "rel err < 2^(1-k)",
        cfg(2000),
        |rng| (rng.normal() * 1e3, rng.below(52) as u32 + 1),
        |&(x, k)| {
            if x == 0.0 {
                return true;
            }
            let t = truncate_f64(x, k);
            ((t - x) / x).abs() < 2f64.powi(1 - k as i32)
        },
    );
}

#[test]
fn prop_coarser_truncation_composes() {
    check(
        "trunc_b ∘ trunc_a = trunc_min(a,b)",
        cfg(2000),
        |rng| (rng.normal() as f32, rng.below(24) as u32 + 1, rng.below(24) as u32 + 1),
        |&(x, a, b)| {
            truncate_f32(truncate_f32(x, a), b) == truncate_f32(x, a.min(b))
        },
    );
}

#[test]
fn prop_used_bits_reconstructs_exactly() {
    // keeping used_bits(x) bits must be lossless
    check(
        "truncate(x, used_bits(x)) == x",
        cfg(2000),
        |rng| rng.normal() * 10f64.powi(rng.below(40) as i32 - 20),
        |&x| truncate_f64(x, used_bits_f64(x)) == x,
    );
}

// --- NSGA-II invariants ----------------------------------------------

#[test]
fn prop_non_dominated_sort_rank_zero_is_pareto() {
    check(
        "rank-0 = non-dominated",
        cfg(60),
        |rng| {
            let n = 3 + rng.below(40) as usize;
            (0..n)
                .map(|_| Evaluated {
                    genome: vec![],
                    objectives: Objectives { error: rng.f64(), energy: rng.f64() },
                })
                .collect::<Vec<_>>()
        },
        |pop| {
            let ranks = non_dominated_sort(pop);
            pop.iter().enumerate().all(|(i, a)| {
                let dominated =
                    pop.iter().any(|b| b.objectives.dominates(&a.objectives));
                (ranks[i] == 0) == !dominated
            })
        },
    );
}

#[test]
fn prop_nsga2_respects_bounds_and_budget() {
    check(
        "nsga2 genes in bounds, budget exact",
        cfg(12),
        |rng| Nsga2Params {
            population: 8 + rng.below(12) as usize,
            generations: 1 + rng.below(4) as usize,
            seed: rng.next_u64(),
            ..Default::default()
        },
        |params| {
            let problem = FnProblem {
                len: 5,
                max_bits: 24,
                f: |g: &Genome| {
                    let m = g.iter().map(|&x| x as f64).sum::<f64>() / (5.0 * 24.0);
                    Objectives { error: 1.0 - m, energy: m }
                },
            };
            let archive = Nsga2::new(params.clone()).run(&problem);
            archive.len() == params.population * (params.generations + 1)
                && archive
                    .iter()
                    .all(|e| e.genome.iter().all(|&g| (1..=24).contains(&g)))
        },
    );
}

#[test]
fn prop_pareto_front_mutually_non_dominating() {
    check(
        "front members incomparable",
        cfg(40),
        |rng| {
            (0..30)
                .map(|_| Evaluated {
                    genome: vec![rng.below(24) as u32 + 1],
                    objectives: Objectives { error: rng.f64(), energy: rng.f64() },
                })
                .collect::<Vec<_>>()
        },
        |archive| {
            let front = pareto_front(archive);
            front.iter().all(|a| {
                !front.iter().any(|b| b.objectives.dominates(&a.objectives))
            })
        },
    );
}

// --- hull invariants ---------------------------------------------------

#[test]
fn prop_hull_below_all_points() {
    check(
        "hull under point cloud",
        cfg(80),
        |rng| {
            (0..50)
                .map(|_| TradeoffPoint::new(rng.f64() * 0.2, rng.f64()))
                .collect::<Vec<_>>()
        },
        |pts| {
            let hull = lower_convex_hull(pts);
            if hull.len() < 2 {
                return true;
            }
            // every input point lies on or above every hull segment
            // (within its error span)
            pts.iter().all(|p| {
                hull.windows(2).all(|seg| {
                    let (a, b) = (seg[0], seg[1]);
                    if p.error < a.error || p.error > b.error || a.error == b.error {
                        return true;
                    }
                    let t = (p.error - a.error) / (b.error - a.error);
                    let line = a.energy + t * (b.energy - a.energy);
                    p.energy >= line - 1e-9
                })
            })
        },
    );
}

#[test]
fn prop_hull_subset_of_points() {
    check(
        "hull ⊆ points",
        cfg(80),
        |rng| {
            (0..30)
                .map(|_| TradeoffPoint::new(rng.f64(), rng.f64()))
                .collect::<Vec<_>>()
        },
        |pts| {
            lower_convex_hull(pts)
                .iter()
                .all(|h| pts.iter().any(|p| p == h))
        },
    );
}

// --- placement routing invariants --------------------------------------

#[test]
fn prop_cip_routes_exactly_by_current_function() {
    // random call trees: a FLOP's FPI is decided solely by its innermost
    // function, never by depth or history
    check(
        "CIP routing",
        cfg(60),
        |rng| {
            let widths: Vec<u32> = (0..4).map(|_| rng.below(24) as u32 + 1).collect();
            let script: Vec<(usize, usize)> = (0..12)
                .map(|_| (rng.below(4) as usize, rng.below(4) as usize))
                .collect();
            (widths, script)
        },
        |(widths, script)| {
            let lib = FpiLibrary::truncation_family(Precision::Single);
            let mut map = HashMap::new();
            let names = ["f0", "f1", "f2", "f3"];
            for (i, &w) in widths.iter().enumerate() {
                map.insert(names[i].to_string(), FpiLibrary::truncation_id(w));
            }
            let mut ctx = FpContext::new(lib, Placement::current_function(map));
            let ids: Vec<_> = names.iter().map(|n| ctx.register(n)).collect();
            script.iter().all(|&(outer, inner)| {
                let expected = truncate_f32(
                    truncate_f32(1.767_123_4, widths[inner])
                        * truncate_f32(1.767_123_4, widths[inner]),
                    widths[inner],
                );
                let got = ctx.call(ids[outer], |c| {
                    c.call(ids[inner], |c| c.mul32(1.767_123_4, 1.767_123_4))
                });
                got == expected
            })
        },
    );
}

#[test]
fn prop_engine_flop_count_is_exact() {
    // the engine's census equals the program's literal op count
    check(
        "census == executed ops",
        cfg(60),
        |rng| (1 + rng.below(200) as usize, 1 + rng.below(100) as usize),
        |&(adds, muls)| {
            let mut ctx = FpContext::profiler();
            let f = ctx.register("work");
            ctx.call(f, |c| {
                let mut acc = 1.0f32;
                for _ in 0..adds {
                    acc = c.add32(acc, 0.5);
                }
                for _ in 0..muls {
                    acc = c.mul64(acc as f64, 1.01) as f32;
                }
                acc
            });
            ctx.counters().total_flops() == (adds + muls) as u64
        },
    );
}

// --- tuner refinement invariants ------------------------------------

use neat::tuner::{DescentStrategy, TuneGoal, Tuner, TunerConfig};

#[test]
fn prop_lattice_descent_matches_binary_rung_on_monotone_problems() {
    // On additively separable problems with constant per-bit error
    // costs (error monotone in every gene, energy proportional to total
    // width), the speculative lattice's deepest feasible rung *is* the
    // binary search's fixed point, and with well-separated costs both
    // strategies walk the genes in the same sensitivity order — so the
    // two tunes must land on the identical configuration. The costs are
    // kept ≥ 1.5× apart so floating-point noise in the per-bit ranking
    // can never flip the order between the strategies' reference points.
    check(
        "lattice == binary rung (separable monotone)",
        cfg(64),
        |rng| {
            let max_bits = 6 + rng.below(19) as u32; // 6..=24
            let base = 1e-4 * (1 + rng.below(50)) as f64;
            let (c0, c1) = if rng.below(2) == 0 {
                (base, base * (1.5 + rng.below(100) as f64 / 50.0))
            } else {
                (base * (1.5 + rng.below(100) as f64 / 50.0), base)
            };
            // a budget somewhere inside the reachable error range
            let span = (c0 + c1) * (max_bits - 1) as f64;
            let eps = span * (0.05 + 0.9 * rng.below(1000) as f64 / 1000.0);
            (max_bits, c0, c1, eps)
        },
        |&(max_bits, c0, c1, eps)| {
            let run = |strategy| {
                let p = FnProblem {
                    len: 2,
                    max_bits,
                    f: move |g: &Genome| Objectives {
                        error: (max_bits - g[0]) as f64 * c0
                            + (max_bits - g[1]) as f64 * c1,
                        energy: (g[0] + g[1]) as f64 / (2 * max_bits) as f64,
                    },
                };
                let mut config = TunerConfig::new(TuneGoal::ErrorBudget(eps));
                config.strategy = strategy;
                config.exchange_rounds = 0;
                Tuner::new(config).run(&p)
            };
            let lattice = run(DescentStrategy::Lattice);
            let binary = run(DescentStrategy::BinaryRung);
            lattice.genome == binary.genome
                && lattice.objectives.energy.to_bits() == binary.objectives.energy.to_bits()
                && lattice.objectives.error.to_bits() == binary.objectives.error.to_bits()
        },
    );
}

#[test]
fn prop_exchange_moves_stay_feasible_and_strictly_improve() {
    // Random coupled problems: whatever the landscape, every accepted
    // exchange must stay inside the error budget and strictly drain
    // energy, and a feasible tune must end inside the budget.
    check(
        "exchanges feasible + strictly improving",
        cfg(48),
        |rng| {
            let max_bits = 8 + rng.below(17) as u32; // 8..=24
            let c: Vec<f64> = (0..3).map(|_| 1e-4 * (1 + rng.below(40)) as f64).collect();
            let w: Vec<f64> = (0..3).map(|_| 1.0 + rng.below(5) as f64).collect();
            let coupling = 1e-6 * rng.below(100) as f64;
            let eps = 1e-3 * (1 + rng.below(60)) as f64;
            (max_bits, c, w, coupling, eps)
        },
        |(max_bits, c, w, coupling, eps)| {
            let (max_bits, eps) = (*max_bits, *eps);
            let (c, w, coupling) = (c.clone(), w.clone(), *coupling);
            let wsum: f64 = w.iter().sum::<f64>() * max_bits as f64;
            let p = FnProblem {
                len: 3,
                max_bits,
                f: move |g: &Genome| {
                    let lost: Vec<f64> =
                        g.iter().map(|&x| (max_bits - x) as f64).collect();
                    Objectives {
                        error: lost.iter().zip(&c).map(|(l, ci)| l * ci).sum::<f64>()
                            + coupling * lost[0] * lost[1],
                        energy: g
                            .iter()
                            .zip(&w)
                            .map(|(&x, wi)| x as f64 * wi)
                            .sum::<f64>()
                            / wsum,
                    }
                },
            };
            let result = Tuner::error_budget(eps).run(&p);
            let mut last_energy = f64::INFINITY;
            let exchanges_ok = result.exchanges.iter().all(|x| {
                let ok = x.objectives.error <= eps + 1e-12
                    && x.objectives.energy < last_energy
                    && x.lowered_from == x.lowered_to + 1
                    && x.raised_from + 1 == x.raised_to;
                last_energy = x.objectives.energy;
                ok
            });
            let final_ok = !result.feasible || result.objectives.error <= eps + 1e-12;
            exchanges_ok && final_ok && result.probes_used <= 400
        },
    );
}
