//! Integration tests: NSGA-II + coordinator over a real benchmark.

use neat::bench_suite::blackscholes::Blackscholes;
use neat::coordinator::experiments::{explore_rule, Budget, THRESHOLDS};
use neat::coordinator::{EvalProblem, Evaluator, RuleKind};
use neat::explore::random_search;
use neat::stats::{lower_convex_hull, savings_at_thresholds};

fn evaluator() -> Evaluator {
    Evaluator::new(Box::new(Blackscholes { options: 80 }), None)
}

#[test]
fn cip_search_finds_savings_within_one_percent_error() {
    let eval = evaluator();
    let res = explore_rule(&eval, RuleKind::Cip, Budget::default());
    let sav = savings_at_thresholds(&res.fpu_points(), &THRESHOLDS);
    // blackscholes is precision-tolerant: expect real savings at 1%
    assert!(sav[0] < 0.9, "NEC@1% = {} (no savings found)", sav[0]);
    // and monotone over increasing budgets
    assert!(sav[0] >= sav[1] && sav[1] >= sav[2]);
}

#[test]
fn nsga2_beats_random_search_at_equal_budget() {
    let eval = evaluator();
    let ga = explore_rule(&eval, RuleKind::Cip, Budget::default());
    let n = ga.details.len();

    let problem = EvalProblem::new(&eval, RuleKind::Cip);
    random_search(&problem, n, 42);
    let rand_details = problem.take_details();
    let rand_points: Vec<_> = rand_details
        .iter()
        .map(|(_, d)| neat::stats::TradeoffPoint::new(d.error, d.fpu_nec))
        .collect();

    let ga_sav = savings_at_thresholds(&ga.fpu_points(), &[0.05]);
    let rand_sav = savings_at_thresholds(&rand_points, &[0.05]);
    assert!(
        ga_sav[0] <= rand_sav[0] + 0.02,
        "GA ({}) should not lose clearly to random ({})",
        ga_sav[0],
        rand_sav[0]
    );
}

#[test]
fn hull_of_search_is_convex_and_anchored() {
    let eval = evaluator();
    let res = explore_rule(&eval, RuleKind::Cip, Budget::quick());
    let pts = res.fpu_points();
    let hull = lower_convex_hull(&pts);
    assert!(!hull.is_empty());
    // anchors guarantee a zero-error point exists
    assert!(hull[0].error == 0.0, "hull must start at the exact config");
    for w in hull.windows(2) {
        assert!(w[0].error <= w[1].error);
        assert!(w[0].energy >= w[1].energy);
    }
}

#[test]
fn search_is_reproducible() {
    let eval = evaluator();
    let a = explore_rule(&eval, RuleKind::Cip, Budget::quick());
    let b = explore_rule(&eval, RuleKind::Cip, Budget::quick());
    let ga: Vec<_> = a.details.iter().map(|(g, _)| g.clone()).collect();
    let gb: Vec<_> = b.details.iter().map(|(g, _)| g.clone()).collect();
    assert_eq!(ga, gb);
}

#[test]
fn train_test_generalization_correlates() {
    let eval = evaluator();
    let res = explore_rule(&eval, RuleKind::Cip, Budget::quick());
    let front = res.front();
    assert!(front.len() >= 3, "front too small to correlate");
    let mut train_err = Vec::new();
    let mut test_err = Vec::new();
    for (g, d) in front.iter().take(12) {
        let t = eval.evaluate_test(RuleKind::Cip, g);
        train_err.push(d.error);
        test_err.push(t.error);
    }
    let r = neat::stats::pearson(&train_err, &test_err);
    assert!(r > 0.8, "train/test error correlation too low: {r}");
}
