//! Integration tests for the sharded suite orchestrator: byte-identical
//! reports/artifacts between the serial walk and a sharded run, resume
//! skipping completed shards, and kill-and-resume converging to the
//! uninterrupted state.

use std::fs;
use std::path::{Path, PathBuf};

use neat::coordinator::experiments::{fig6, Budget};
use neat::coordinator::suite::{artifact_canonical, SuiteConfig, SuiteOutcome, SuiteRunner};
use neat::report::ResultsDir;

const BENCHES: [&str; 2] = ["blackscholes", "kmeans"];

fn config(threads: usize, run_dir: Option<PathBuf>, resume: bool) -> SuiteConfig {
    let mut cfg = SuiteConfig::new(Budget::quick());
    cfg.threads = threads;
    cfg.run_dir = run_dir;
    cfg.resume = resume;
    cfg.benchmarks = Some(BENCHES.iter().map(|s| s.to_string()).collect());
    cfg
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("neat_suite_it_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn run(cfg: SuiteConfig) -> SuiteOutcome {
    SuiteRunner::new(cfg).run(&mut |_m: &str| {}).expect("suite run")
}

fn assert_results_bitwise_equal(a: &SuiteOutcome, b: &SuiteOutcome) {
    assert_eq!(a.results.len(), b.results.len());
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.name, y.name, "suite order must match");
        for (rx, ry) in [(&x.wp, &y.wp), (&x.cip, &y.cip)] {
            assert_eq!(rx.details.len(), ry.details.len(), "{}: archive size", x.name);
            for ((ga, da), (gb, db)) in rx.details.iter().zip(&ry.details) {
                assert_eq!(ga, gb, "{}: genome order must match", x.name);
                assert_eq!(da.error.to_bits(), db.error.to_bits());
                assert_eq!(da.fpu_nec.to_bits(), db.fpu_nec.to_bits());
                assert_eq!(da.mem_nec.to_bits(), db.mem_nec.to_bits());
                assert_eq!(da.fpu_target_nec.to_bits(), db.fpu_target_nec.to_bits());
            }
        }
    }
}

fn canonical_artifacts(dir: &Path) -> Vec<(String, String)> {
    BENCHES
        .iter()
        .map(|b| {
            let text = fs::read_to_string(dir.join(format!("{b}.json")))
                .unwrap_or_else(|e| panic!("missing artifact for {b}: {e}"));
            (b.to_string(), artifact_canonical(&text))
        })
        .collect()
}

/// The acceptance bar: a 4-thread sharded run produces the same archive
/// bits, the same report text, and (up to wall clock) the same artifact
/// bytes as the serial benchmark walk — and a killed run, resumed,
/// converges to the uninterrupted state.
#[test]
fn sharded_run_matches_serial_walk_and_resumes_after_kill() {
    let dir_serial = tmp_dir("serial");
    let dir_sharded = tmp_dir("sharded");

    let serial = run(config(1, Some(dir_serial.clone()), false));
    let sharded = run(config(4, Some(dir_sharded.clone()), false));
    assert_eq!(serial.executed, BENCHES.to_vec());
    assert!(serial.resumed.is_empty());
    assert!(sharded.plan.concurrent_shards >= 2, "4 threads must shard");
    assert_results_bitwise_equal(&serial, &sharded);

    // artifact files byte-identical up to the wall-clock field
    let arts_serial = canonical_artifacts(&dir_serial);
    let arts_sharded = canonical_artifacts(&dir_sharded);
    assert_eq!(arts_serial, arts_sharded);

    // reports assembled from both runs are byte-identical
    let rd_a = ResultsDir::new(std::env::temp_dir().join("neat_suite_it_rd_a")).unwrap();
    let rd_b = ResultsDir::new(std::env::temp_dir().join("neat_suite_it_rd_b")).unwrap();
    let fig6_serial = fig6(&rd_a, &serial.results).unwrap();
    let fig6_sharded = fig6(&rd_b, &sharded.results).unwrap();
    assert_eq!(fig6_serial, fig6_sharded);

    // simulate a kill: one shard's artifact is complete, the other torn
    let dir_killed = tmp_dir("killed");
    fs::copy(
        dir_serial.join(format!("{}.json", BENCHES[0])),
        dir_killed.join(format!("{}.json", BENCHES[0])),
    )
    .unwrap();
    let full = fs::read_to_string(dir_serial.join(format!("{}.json", BENCHES[1]))).unwrap();
    fs::write(dir_killed.join(format!("{}.json", BENCHES[1])), &full[..full.len() / 3])
        .unwrap();

    let resumed = run(config(4, Some(dir_killed.clone()), true));
    assert_eq!(resumed.resumed, vec![BENCHES[0].to_string()], "complete shard is skipped");
    assert_eq!(resumed.executed, vec![BENCHES[1].to_string()], "torn shard is re-run");
    assert_results_bitwise_equal(&serial, &resumed);
    assert_eq!(arts_serial, canonical_artifacts(&dir_killed));
}

/// A second `--resume` pass over a completed run directory executes
/// nothing, and still reproduces the run bit-for-bit from artifacts.
#[test]
fn resume_skips_completed_shards() {
    let dir = tmp_dir("resume");
    let first = run(config(2, Some(dir.clone()), false));
    assert_eq!(first.executed.len(), BENCHES.len());

    let second = run(config(2, Some(dir.clone()), true));
    assert!(second.executed.is_empty(), "resume must skip completed shards");
    assert_eq!(second.resumed, BENCHES.to_vec());
    assert_results_bitwise_equal(&first, &second);

    // without --resume the artifacts are ignored and recomputed
    let third = run(config(2, Some(dir.clone()), false));
    assert_eq!(third.executed.len(), BENCHES.len());
    assert!(third.resumed.is_empty());
    assert_results_bitwise_equal(&first, &third);
}
