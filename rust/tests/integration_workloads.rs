//! Integration tests over the full benchmark suite: every workload must
//! satisfy the contract the coordinator depends on.

use neat::bench_suite::{self, Workload};
use neat::coordinator::{Evaluator, RuleKind};
use neat::engine::profile::Profile;
use neat::engine::FpContext;
use neat::fpi::{FpiLibrary, Precision};
use neat::placement::Placement;

/// Exact runs are deterministic for the same seed and differ across
/// seeds (otherwise "multiple inputs" would be a fiction).
#[test]
fn all_workloads_deterministic_and_seed_sensitive() {
    for w in bench_suite::all() {
        let s = w.train_seeds()[0];
        let a = w.run(&mut FpContext::profiler(), s);
        let b = w.run(&mut FpContext::profiler(), s);
        assert_eq!(a, b, "{} not deterministic", w.name());
        let c = w.run(&mut FpContext::profiler(), w.test_seeds()[0]);
        assert_ne!(a, c, "{} ignores its input seed", w.name());
    }
}

/// Outputs are finite at full precision.
#[test]
fn all_workloads_finite_baseline() {
    for w in bench_suite::all() {
        let out = w.run(&mut FpContext::profiler(), w.train_seeds()[0]);
        assert!(!out.is_empty(), "{} returned nothing", w.name());
        assert!(
            out.iter().all(|v| v.is_finite()),
            "{} produced non-finite output",
            w.name()
        );
    }
}

/// Every function a workload advertises actually executes FLOPs on at
/// least one training input (placement targets must be real).
#[test]
fn advertised_functions_execute() {
    for w in bench_suite::all() {
        let mut ctx = FpContext::profiler();
        for seed in w.train_seeds().iter().take(2) {
            w.run(&mut ctx, *seed);
        }
        let profile = Profile::from_context(&ctx);
        for f in w.functions() {
            let row = profile.rows.iter().find(|r| r.name == f);
            assert!(
                row.is_some_and(|r| r.total() > 0 || r.mem_ops > 0),
                "{}::{f} never executed work",
                w.name()
            );
        }
    }
}

/// The top-10 functions must cover ≥95% of FLOPs (the paper reports
/// ≥98% on its suite; our reimplementations stay close).
#[test]
fn top10_coverage_is_high() {
    for w in bench_suite::table2() {
        let mut ctx = FpContext::profiler();
        w.run(&mut ctx, w.train_seeds()[0]);
        let profile = Profile::from_context(&ctx);
        let cov = profile.coverage(10);
        assert!(cov > 0.95, "{} top-10 coverage only {:.1}%", w.name(), cov * 100.0);
    }
}

/// Whole-program truncation at 1 bit must visibly damage every
/// workload's output (no workload is insensitive to precision), while
/// full width must reproduce the baseline bit-for-bit.
#[test]
fn precision_sensitivity_bounds() {
    for w in bench_suite::all() {
        let seed = w.train_seeds()[0];
        let base = w.run(&mut FpContext::profiler(), seed);

        let target = w.default_target();
        let lib = FpiLibrary::truncation_family(target);
        let full_bits = target.mantissa_bits();
        let mut full_ctx = FpContext::new(
            lib.clone(),
            Placement::whole_program(FpiLibrary::truncation_id(full_bits)),
        );
        full_ctx.set_target(target); // paper step 2: gate by precision
        let full = w.run(&mut full_ctx, seed);
        assert_eq!(w.error(&base, &full), 0.0, "{} full-width run differs", w.name());

        let mut one_ctx =
            FpContext::new(lib, Placement::whole_program(FpiLibrary::truncation_id(1)));
        one_ctx.set_target(target);
        let one = w.run(&mut one_ctx, seed);
        let err = w.error(&base, &one);
        assert!(err > 1e-3, "{} unaffected by 1-bit truncation (err {err})", w.name());
    }
}

/// The mixed-precision benchmarks really carry both FLOP types, and the
/// single/double-dominant ones match their declared targets (Fig. 4).
#[test]
fn precision_profiles_match_declarations() {
    for w in bench_suite::all() {
        let mut ctx = FpContext::profiler();
        w.run(&mut ctx, w.train_seeds()[0]);
        let p = Profile::from_context(&ctx);
        let frac = p.single_fraction();
        match w.name() {
            "particlefilter" | "canneal" => {
                assert!(frac < 0.2, "{} should be double-dominant ({frac})", w.name())
            }
            "ferret" => assert!(
                (0.2..0.8).contains(&frac),
                "ferret should be mixed ({frac})"
            ),
            "srad" => assert!(
                (0.5..0.995).contains(&frac),
                "srad should carry some double ({frac})"
            ),
            _ => assert!(frac > 0.9, "{} should be single-dominant ({frac})", w.name()),
        }
    }
}

/// Radar: the FCS rule must reach configurations CIP cannot express —
/// different effective precision for fft-under-lpf vs fft-under-pc.
#[test]
fn radar_fcs_distinguishes_callers() {
    use std::collections::HashMap;
    let w = bench_suite::by_name("radar").unwrap();
    let seed = w.train_seeds()[0];
    let base = w.run(&mut FpContext::profiler(), seed);

    // lpf gets 24 bits, pc gets 24 bits -> near-baseline
    let lib = FpiLibrary::truncation_family(Precision::Single);
    let mut map = HashMap::new();
    for f in ["lpf", "pc", "gen_pulse", "window", "magnitude", "doppler",
              "accumulate", "decimate", "detect", "ref_chirp"] {
        map.insert(f.to_string(), FpiLibrary::truncation_id(24));
    }
    let mut ctx = FpContext::new(lib.clone(), Placement::call_stack(map.clone()));
    let out = w.run(&mut ctx, seed);
    assert_eq!(w.error(&base, &out), 0.0);

    // now degrade ONLY the lpf subtree (fft inherits via call stack)
    map.insert("lpf".to_string(), FpiLibrary::truncation_id(2));
    let mut ctx = FpContext::new(lib.clone(), Placement::call_stack(map.clone()));
    let lpf_out = w.run(&mut ctx, seed);
    let lpf_err = w.error(&base, &lpf_out);

    // vs degrading ONLY the pc subtree
    map.insert("lpf".to_string(), FpiLibrary::truncation_id(24));
    map.insert("pc".to_string(), FpiLibrary::truncation_id(2));
    let mut ctx = FpContext::new(lib, Placement::call_stack(map));
    let pc_out = w.run(&mut ctx, seed);
    let pc_err = w.error(&base, &pc_out);

    assert!(lpf_err > 0.0 && pc_err > 0.0);
    assert_ne!(lpf_out, pc_out, "caller-split truncation must differ");
}

/// Evaluator construction works for every workload and both targets
/// where meaningful.
#[test]
fn evaluators_construct_for_all_benchmarks() {
    for w in bench_suite::table2() {
        let name = w.name().to_string();
        let eval = Evaluator::new(w, None);
        assert!(!eval.top_functions.is_empty(), "{name}: no top functions");
        assert!(eval.genome_len(RuleKind::Cip) >= 4, "{name}: genome too small");
        let d = eval.evaluate_train(
            RuleKind::Cip,
            &vec![eval.target.mantissa_bits(); eval.genome_len(RuleKind::Cip)],
        );
        assert_eq!(d.error, 0.0, "{name}: full-width config not lossless");
    }
}
