//! Integration tests for the heuristic precision tuner riding the
//! batch executor: determinism (serial vs worker pool), constraint
//! satisfaction, monotonicity across budgets, the evaluation-budget
//! ceiling (counted via the coordinator's genome cache), and the
//! paper's "no worse than the best whole-program width" bar.

use neat::bench_suite::blackscholes::Blackscholes;
use neat::coordinator::experiments::{explore_rule_with, Budget};
use neat::coordinator::{EvalProblem, Evaluator, Executor, RuleKind};
use neat::explore::Problem;
use neat::stats::savings_at_thresholds;
use neat::tuner::{TuneGoal, Tuner, TunerConfig};

fn evaluator() -> Evaluator {
    Evaluator::new(Box::new(Blackscholes { options: 60 }), None)
}

/// The tuner is RNG-free and every probe is a pure function of the
/// genome, so a serial executor and a 4-thread pool must produce the
/// identical tune: same genome, bit-identical objectives, same probe
/// count.
#[test]
fn tune_deterministic_serial_vs_parallel() {
    let eval = evaluator();
    let run = |exec: Executor| {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, exec);
        Tuner::error_budget(0.05).run(&problem)
    };
    let serial = run(Executor::serial());
    let parallel = run(Executor::new(4));
    assert_eq!(serial.genome, parallel.genome);
    assert_eq!(
        serial.objectives.error.to_bits(),
        parallel.objectives.error.to_bits()
    );
    assert_eq!(
        serial.objectives.energy.to_bits(),
        parallel.objectives.energy.to_bits()
    );
    assert_eq!(serial.probes_used, parallel.probes_used);
    assert_eq!(serial.steps.len(), parallel.steps.len());
    // the full probe logs agree entry by entry
    assert_eq!(serial.log.len(), parallel.log.len());
    for ((ga, oa), (gb, ob)) in serial.log.iter().zip(&parallel.log) {
        assert_eq!(ga, gb);
        assert_eq!(oa.error.to_bits(), ob.error.to_bits());
        assert_eq!(oa.energy.to_bits(), ob.energy.to_bits());
    }
}

/// Tightening the error budget never loosens the result: the tight
/// config's error stays within its own (smaller) budget and does not
/// exceed the loose config's error, while its energy can only be higher.
#[test]
fn tune_monotone_in_error_budget() {
    let eval = evaluator();
    let run = |eps: f64| {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
        Tuner::error_budget(eps).run(&problem)
    };
    let tight = run(0.01);
    let loose = run(0.10);
    assert!(tight.feasible && loose.feasible);
    assert!(tight.objectives.error <= 0.01 + 1e-12);
    assert!(loose.objectives.error <= 0.10 + 1e-12);
    assert!(
        tight.objectives.error <= loose.objectives.error + 1e-9,
        "tightening the budget increased error: {} vs {}",
        tight.objectives.error,
        loose.objectives.error
    );
    assert!(
        loose.objectives.energy <= tight.objectives.energy + 1e-9,
        "loosening the budget increased energy: {} vs {}",
        loose.objectives.energy,
        tight.objectives.energy
    );
}

/// The evaluation budget is a hard ceiling on *executed* configurations,
/// counted via the coordinator's genome memo cache: unique executions
/// (cache misses) never exceed the tuner's budget.
#[test]
fn tune_budget_ceiling_via_genome_cache() {
    let eval = evaluator();
    for max_evals in [25usize, 60] {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
        let config = TunerConfig { goal: TuneGoal::ErrorBudget(0.05), max_evals };
        let result = Tuner::new(config).run(&problem);
        let (_hits, misses) = problem.cache_stats();
        assert!(
            misses <= max_evals,
            "{misses} unique executions exceed the {max_evals}-probe budget"
        );
        assert!(result.probes_used <= max_evals);
        assert_eq!(result.log.len(), result.probes_used);
    }
}

/// The acceptance bar from the paper's abstract comparison: at the 1%
/// and 10% error budgets the per-function heuristic tune must save at
/// least as much FPU energy as the best single whole-program width at
/// the same budget. Blackscholes places every FLOP inside its four
/// mapped functions, so the tuner's uniform-CIP seed ladder coincides
/// with the WP sweep exactly and descent only lowers energy from there
/// — the bound is structural here, not statistical.
#[test]
fn tune_beats_best_wp_at_paper_budgets() {
    let eval = evaluator();
    let exec = Executor::serial();
    let wp = explore_rule_with(&eval, RuleKind::Wp, Budget::quick(), &exec);
    let wp_nec = savings_at_thresholds(&wp.fpu_points(), &[0.01, 0.10]);
    for (i, eps) in [0.01, 0.10].into_iter().enumerate() {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, exec.clone());
        let tuned = Tuner::error_budget(eps).run(&problem);
        assert!(tuned.feasible, "blackscholes must be tunable at {eps}");
        assert!(tuned.objectives.error <= eps + 1e-12);
        assert!(
            tuned.objectives.energy <= wp_nec[i] + 1e-9,
            "tuner NEC {} worse than best WP {} at ε={eps}",
            tuned.objectives.energy,
            wp_nec[i]
        );
    }
}

/// Energy-budget (inverse) mode: the result respects ψ and improves on
/// the cheapest configuration's error.
#[test]
fn tune_energy_budget_mode() {
    let eval = evaluator();
    let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
    let psi = 0.7;
    let result = Tuner::energy_budget(psi).run(&problem);
    assert!(result.feasible);
    assert!(result.objectives.energy <= psi + 1e-12);
    assert!(result.objectives.error.is_finite());
    // the all-min configuration is the energy floor; the tuner should
    // have bought some accuracy back relative to it
    let floor = problem.eval.evaluate_train(RuleKind::Cip, &vec![1; problem.genome_len()]);
    assert!(result.objectives.error <= floor.error + 1e-12);
}

/// WP tuning degenerates to picking the best rung of the uniform ladder
/// — i.e. exactly the WP sweep's answer.
#[test]
fn wp_tune_matches_wp_sweep() {
    let eval = evaluator();
    let exec = Executor::serial();
    let wp = explore_rule_with(&eval, RuleKind::Wp, Budget::quick(), &exec);
    let wp_nec = savings_at_thresholds(&wp.fpu_points(), &[0.05]);
    let problem = EvalProblem::with_executor(&eval, RuleKind::Wp, exec.clone());
    let tuned = Tuner::error_budget(0.05).run(&problem);
    assert!(tuned.feasible);
    assert!((tuned.objectives.energy - wp_nec[0]).abs() < 1e-12);
}
