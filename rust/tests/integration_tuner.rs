//! Integration tests for the heuristic precision tuner riding the
//! batch executor: determinism (serial vs worker pool), constraint
//! satisfaction, monotonicity across budgets, the evaluation-budget
//! ceiling (counted via the coordinator's genome cache), the paper's
//! "no worse than the best whole-program width" bar, exchange-move
//! safety, lattice-vs-binary descent parity, and the NSGA-II warm
//! start handoff.

use neat::bench_suite::blackscholes::Blackscholes;
use neat::coordinator::experiments::{explore_rule_with, Budget};
use neat::coordinator::{EvalProblem, Evaluator, Executor, RuleKind};
use neat::explore::{
    Evaluated, FnProblem, Genome, Nsga2, Nsga2Params, Objectives, Problem,
};
use neat::stats::{savings_at_thresholds, TradeoffPoint};
use neat::tuner::{warm_start_genomes, DescentStrategy, TuneGoal, Tuner, TunerConfig};

fn evaluator() -> Evaluator {
    Evaluator::new(Box::new(Blackscholes { options: 60 }), None)
}

/// The tuner is RNG-free and every probe is a pure function of the
/// genome, so a serial executor and a 4-thread pool must produce the
/// identical tune: same genome, bit-identical objectives, same probe
/// count.
#[test]
fn tune_deterministic_serial_vs_parallel() {
    let eval = evaluator();
    let run = |exec: Executor| {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, exec);
        Tuner::error_budget(0.05).run(&problem)
    };
    let serial = run(Executor::serial());
    let parallel = run(Executor::new(4));
    assert_eq!(serial.genome, parallel.genome);
    assert_eq!(
        serial.objectives.error.to_bits(),
        parallel.objectives.error.to_bits()
    );
    assert_eq!(
        serial.objectives.energy.to_bits(),
        parallel.objectives.energy.to_bits()
    );
    assert_eq!(serial.probes_used, parallel.probes_used);
    assert_eq!(serial.steps.len(), parallel.steps.len());
    // the full probe logs agree entry by entry
    assert_eq!(serial.log.len(), parallel.log.len());
    for ((ga, oa), (gb, ob)) in serial.log.iter().zip(&parallel.log) {
        assert_eq!(ga, gb);
        assert_eq!(oa.error.to_bits(), ob.error.to_bits());
        assert_eq!(oa.energy.to_bits(), ob.energy.to_bits());
    }
}

/// Tightening the error budget never loosens the result: the tight
/// config's error stays within its own (smaller) budget and does not
/// exceed the loose config's error, while its energy can only be higher.
#[test]
fn tune_monotone_in_error_budget() {
    let eval = evaluator();
    let run = |eps: f64| {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
        Tuner::error_budget(eps).run(&problem)
    };
    let tight = run(0.01);
    let loose = run(0.10);
    assert!(tight.feasible && loose.feasible);
    assert!(tight.objectives.error <= 0.01 + 1e-12);
    assert!(loose.objectives.error <= 0.10 + 1e-12);
    assert!(
        tight.objectives.error <= loose.objectives.error + 1e-9,
        "tightening the budget increased error: {} vs {}",
        tight.objectives.error,
        loose.objectives.error
    );
    assert!(
        loose.objectives.energy <= tight.objectives.energy + 1e-9,
        "loosening the budget increased energy: {} vs {}",
        loose.objectives.energy,
        tight.objectives.energy
    );
}

/// The evaluation budget is a hard ceiling on *executed* configurations,
/// counted via the coordinator's genome memo cache: unique executions
/// (cache misses) never exceed the tuner's budget.
#[test]
fn tune_budget_ceiling_via_genome_cache() {
    let eval = evaluator();
    for max_evals in [25usize, 60] {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
        let mut config = TunerConfig::new(TuneGoal::ErrorBudget(0.05));
        config.max_evals = max_evals;
        let result = Tuner::new(config).run(&problem);
        let (_hits, misses) = problem.cache_stats();
        assert!(
            misses <= max_evals,
            "{misses} unique executions exceed the {max_evals}-probe budget"
        );
        assert!(result.probes_used <= max_evals);
        assert_eq!(result.log.len(), result.probes_used);
    }
}

/// The acceptance bar from the paper's abstract comparison: at the 1%
/// and 10% error budgets the per-function heuristic tune must save at
/// least as much FPU energy as the best single whole-program width at
/// the same budget. Blackscholes places every FLOP inside its four
/// mapped functions, so the tuner's uniform-CIP seed ladder coincides
/// with the WP sweep exactly and descent only lowers energy from there
/// — the bound is structural here, not statistical.
#[test]
fn tune_beats_best_wp_at_paper_budgets() {
    let eval = evaluator();
    let exec = Executor::serial();
    let wp = explore_rule_with(&eval, RuleKind::Wp, Budget::quick(), &exec);
    let wp_nec = savings_at_thresholds(&wp.fpu_points(), &[0.01, 0.10]);
    for (i, eps) in [0.01, 0.10].into_iter().enumerate() {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, exec.clone());
        let tuned = Tuner::error_budget(eps).run(&problem);
        assert!(tuned.feasible, "blackscholes must be tunable at {eps}");
        assert!(tuned.objectives.error <= eps + 1e-12);
        assert!(
            tuned.objectives.energy <= wp_nec[i] + 1e-9,
            "tuner NEC {} worse than best WP {} at ε={eps}",
            tuned.objectives.energy,
            wp_nec[i]
        );
    }
}

/// Energy-budget (inverse) mode: the result respects ψ and improves on
/// the cheapest configuration's error.
#[test]
fn tune_energy_budget_mode() {
    let eval = evaluator();
    let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
    let psi = 0.7;
    let result = Tuner::energy_budget(psi).run(&problem);
    assert!(result.feasible);
    assert!(result.objectives.energy <= psi + 1e-12);
    assert!(result.objectives.error.is_finite());
    // the all-min configuration is the energy floor; the tuner should
    // have bought some accuracy back relative to it
    let floor = problem.eval.evaluate_train(RuleKind::Cip, &vec![1; problem.genome_len()]);
    assert!(result.objectives.error <= floor.error + 1e-12);
}

/// Exchange moves may only ever trade bits *inside* the feasible
/// region: every accepted exchange keeps the error within the budget,
/// moves exactly one bit each way, and — because exchanges start from
/// the monotone descent's fixed point and accept only strict energy
/// improvements — enabling them can never end with more energy than the
/// exchange-free tune.
#[test]
fn exchange_moves_never_violate_error_budget() {
    let eval = evaluator();
    let eps = 0.05;
    let run = |rounds: usize| {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
        let mut config = TunerConfig::new(TuneGoal::ErrorBudget(eps));
        config.exchange_rounds = rounds;
        Tuner::new(config).run(&problem)
    };
    let with = run(8);
    let without = run(0);
    assert!(without.exchanges.is_empty());
    assert!(with.feasible && without.feasible);
    assert!(with.objectives.error <= eps + 1e-12);
    let mut last_energy = f64::INFINITY;
    for x in &with.exchanges {
        assert!(x.objectives.error <= eps + 1e-12, "exchange broke the error budget");
        assert_eq!(x.lowered_from, x.lowered_to + 1, "exchanges move one bit");
        assert_eq!(x.raised_from + 1, x.raised_to, "exchanges move one bit");
        assert!(x.objectives.energy < last_energy, "exchanges strictly improve");
        last_energy = x.objectives.energy;
    }
    assert!(
        with.objectives.energy <= without.objectives.energy + 1e-12,
        "exchange phase made the tune worse: {} vs {}",
        with.objectives.energy,
        without.objectives.energy
    );
}

/// On a single-gene space the lattice wave sees every width the binary
/// search can visit, so its rung can only be at least as good — and
/// both must keep the budget.
#[test]
fn wp_lattice_no_worse_than_binary_rung() {
    let eval = evaluator();
    let eps = 0.05;
    let run = |strategy| {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Wp, Executor::serial());
        let mut config = TunerConfig::new(TuneGoal::ErrorBudget(eps));
        config.strategy = strategy;
        config.exchange_rounds = 0;
        Tuner::new(config).run(&problem)
    };
    let lattice = run(DescentStrategy::Lattice);
    let binary = run(DescentStrategy::BinaryRung);
    assert!(lattice.feasible && binary.feasible);
    assert!(lattice.objectives.error <= eps + 1e-12);
    assert!(lattice.objectives.energy <= binary.objectives.energy + 1e-12);
}

/// The latency claim behind the speculative lattice: the whole tune
/// fits in one seed wave plus one lattice wave per gene per pass, far
/// below the binary search's per-rung round-trips plus re-ranking
/// waves.
#[test]
fn lattice_tune_uses_fewer_waves_than_binary_rung() {
    let eval = evaluator();
    let run = |strategy| {
        let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
        let mut config = TunerConfig::new(TuneGoal::ErrorBudget(0.05));
        config.strategy = strategy;
        config.exchange_rounds = 0;
        Tuner::new(config).run(&problem)
    };
    let lattice = run(DescentStrategy::Lattice);
    let binary = run(DescentStrategy::BinaryRung);
    assert!(
        lattice.waves < binary.waves,
        "lattice took {} waves, binary {}",
        lattice.waves,
        binary.waves
    );
}

/// Warm-starting NSGA-II with the tuned genome and its one-bit
/// neighborhood guarantees the warm front is at least as good at the
/// constraint point as the tuned configuration itself: the archive
/// contains the tuned point, so the quantized NEC can only improve.
#[test]
fn warm_started_front_at_least_as_good_as_tuner_at_budget() {
    let eval = evaluator();
    let eps = 0.05;
    let exec = Executor::serial();
    let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, exec.clone());
    let tuned = Tuner::error_budget(eps).run(&problem);
    assert!(tuned.feasible);

    let seeds = warm_start_genomes(&tuned.genome, problem.max_bits());
    let warm_problem = EvalProblem::with_executor(&eval, RuleKind::Cip, exec.clone());
    let params =
        Nsga2Params { population: 12, generations: 3, ..Default::default() }.warm_started(seeds);
    Nsga2::new(params).run(&warm_problem);
    let warm_points: Vec<TradeoffPoint> = warm_problem
        .take_details()
        .iter()
        .map(|(_, d)| TradeoffPoint::new(d.error, d.fpu_nec))
        .collect();
    let warm_nec = savings_at_thresholds(&warm_points, &[eps])[0];
    assert!(
        warm_nec <= tuned.objectives.energy + 1e-12,
        "warm front NEC {} worse than the tuned point {}",
        warm_nec,
        tuned.objectives.energy
    );
}

/// On a single-gene problem the tuner provably finds the global optimum
/// (its seed ladder sweeps the entire space), so for any fixed seed a
/// warm-started front dominates-or-ties the cold-started front at the
/// constraint point — the warm archive carries the optimum.
#[test]
fn warm_start_dominates_or_ties_cold_front_at_budget() {
    let p = FnProblem {
        len: 1,
        max_bits: 24,
        f: |g: &Genome| Objectives {
            error: (24 - g[0]) as f64 * 0.01,
            energy: g[0] as f64 / 24.0,
        },
    };
    let eps = 0.05;
    let tuned = Tuner::error_budget(eps).run(&p);
    assert!(tuned.feasible);
    let params = Nsga2Params { population: 8, generations: 3, seed: 7, ..Default::default() };
    let cold = Nsga2::new(params.clone()).run(&p);
    let warm = Nsga2::new(params.warm_started(warm_start_genomes(&tuned.genome, 24))).run(&p);
    let nec_at = |archive: &[Evaluated]| {
        let pts: Vec<TradeoffPoint> = archive
            .iter()
            .map(|e| TradeoffPoint::new(e.objectives.error, e.objectives.energy))
            .collect();
        savings_at_thresholds(&pts, &[eps])[0]
    };
    assert!(
        nec_at(&warm) <= nec_at(&cold) + 1e-12,
        "warm front lost to cold at ε={eps}: {} vs {}",
        nec_at(&warm),
        nec_at(&cold)
    );
    // front density: the warm archive carries the tuned point itself
    assert!(warm.iter().any(|e| e.genome == tuned.genome));
}

/// WP tuning degenerates to picking the best rung of the uniform ladder
/// — i.e. exactly the WP sweep's answer.
#[test]
fn wp_tune_matches_wp_sweep() {
    let eval = evaluator();
    let exec = Executor::serial();
    let wp = explore_rule_with(&eval, RuleKind::Wp, Budget::quick(), &exec);
    let wp_nec = savings_at_thresholds(&wp.fpu_points(), &[0.05]);
    let problem = EvalProblem::with_executor(&eval, RuleKind::Wp, exec.clone());
    let tuned = Tuner::error_budget(0.05).run(&problem);
    assert!(tuned.feasible);
    assert!((tuned.objectives.energy - wp_nec[0]).abs() < 1e-12);
}
