//! Integration tests for the service layer: content-addressed cache
//! key stability and invalidation, corrupted-entry robustness,
//! serve-vs-CLI byte-identical determinism, the cached-resubmit fast
//! path across a daemon restart, graceful-shutdown parking + resume,
//! and the HTTP front end end-to-end over a real localhost socket.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use neat::bench_suite::blackscholes::Blackscholes;
use neat::bench_suite::Workload;
use neat::coordinator::{EvalProblem, Evaluator, Executor, RuleKind};
use neat::engine::FpContext;
use neat::explore::Problem;
use neat::fpi::Precision;
use neat::service::cache::{CacheKey, ResultCache};
use neat::service::{
    http, JobKind, JobSpec, JobState, Service, ServiceConfig, ShardOutput,
};
use neat::tuner::{TuneGoal, Tuner, TunerConfig};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("neat_service_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn evaluator() -> Evaluator {
    Evaluator::new(Box::new(Blackscholes { options: 60 }), None)
}

fn spec(tenant: &str, kind: JobKind) -> JobSpec {
    JobSpec { tenant: tenant.to_string(), priority: 1, target: None, formats: vec![], kind }
}

/// The cache key is an unordered field set: assembling the same fields
/// in a different order must produce the same canonical form and
/// fingerprint, and a changed value must change the fingerprint.
#[test]
fn cache_key_stable_across_field_reordering() {
    let a = CacheKey::new()
        .field("workload", "blackscholes")
        .field("rule", "CIP")
        .field("seeds", "1,2,3")
        .genome(&vec![4, 8]);
    let b = CacheKey::new()
        .genome(&vec![4, 8])
        .field("seeds", "1,2,3")
        .field("rule", "CIP")
        .field("workload", "blackscholes");
    assert_eq!(a.canonical(), b.canonical());
    assert_eq!(a.fingerprint(), b.fingerprint());
    let c = CacheKey::new()
        .field("workload", "blackscholes")
        .field("rule", "CIP")
        .field("seeds", "1,2,3")
        .genome(&vec![4, 9]);
    assert_ne!(a.fingerprint(), c.fingerprint());
}

/// Blackscholes with its workload version bumped — simulates an
/// algorithm/input-generation change that must invalidate old entries.
struct VersionBumped(Blackscholes);

impl Workload for VersionBumped {
    fn name(&self) -> &'static str {
        self.0.name()
    }
    fn default_target(&self) -> Precision {
        self.0.default_target()
    }
    fn functions(&self) -> Vec<&'static str> {
        self.0.functions()
    }
    fn fcs_shared(&self) -> Vec<&'static str> {
        self.0.fcs_shared()
    }
    fn version(&self) -> u32 {
        2
    }
    fn train_seeds(&self) -> Vec<u64> {
        self.0.train_seeds()
    }
    fn test_seeds(&self) -> Vec<u64> {
        self.0.test_seeds()
    }
    fn run(&self, ctx: &mut FpContext, seed: u64) -> Vec<f64> {
        self.0.run(ctx, seed)
    }
    fn error(&self, baseline: &[f64], approx: &[f64]) -> f64 {
        self.0.error(baseline, approx)
    }
}

/// A second problem over the same cache hits; a problem whose workload
/// version was bumped misses — stale cross-run entries are never served
/// as current results.
#[test]
fn workload_version_bump_invalidates_entries() {
    let cache = Arc::new(ResultCache::new(tmp("version")).unwrap());
    let eval = evaluator();
    let genome = vec![10u32; eval.genome_len(RuleKind::Cip)];

    let p1 = EvalProblem::with_cache(&eval, RuleKind::Cip, Executor::serial(), cache.clone());
    let first = p1.evaluate(&genome);
    assert_eq!(p1.persist_stats(), (0, 1), "cold cache must miss");

    let p2 = EvalProblem::with_cache(&eval, RuleKind::Cip, Executor::serial(), cache.clone());
    let second = p2.evaluate(&genome);
    assert_eq!(p2.persist_stats(), (1, 0), "same version must hit");
    assert_eq!(first.error.to_bits(), second.error.to_bits());
    assert_eq!(first.energy.to_bits(), second.energy.to_bits());

    let bumped = Evaluator::new(Box::new(VersionBumped(Blackscholes { options: 60 })), None);
    let p3 =
        EvalProblem::with_cache(&bumped, RuleKind::Cip, Executor::serial(), cache.clone());
    let third = p3.evaluate(&genome);
    assert_eq!(p3.persist_stats(), (0, 1), "bumped version must miss");
    // same algorithm underneath, so the value agrees — only the cache
    // identity changed
    assert_eq!(first.error.to_bits(), third.error.to_bits());
}

/// A corrupted or truncated entry is a miss (re-evaluated and
/// overwritten), never a panic and never a wrong value.
#[test]
fn corrupted_entry_is_a_miss_not_a_panic() {
    let dir = tmp("corrupt");
    let cache = Arc::new(ResultCache::new(&dir).unwrap());
    let eval = evaluator();
    let genome = vec![9u32; eval.genome_len(RuleKind::Cip)];

    let p1 = EvalProblem::with_cache(&eval, RuleKind::Cip, Executor::serial(), cache.clone());
    let clean = p1.evaluate(&genome);
    assert_eq!(cache.entries(), 1);

    // mangle the single entry on disk: truncate to half, then also try
    // plain garbage
    let entry = walk_entries(&dir).pop().expect("one entry on disk");
    let text = std::fs::read_to_string(&entry).unwrap();
    for broken in [&text[..text.len() / 2], "{ not json", ""] {
        std::fs::write(&entry, broken).unwrap();
        let p = EvalProblem::with_cache(&eval, RuleKind::Cip, Executor::serial(), cache.clone());
        let again = p.evaluate(&genome);
        assert_eq!(p.persist_stats(), (0, 1), "defective entry must be a miss");
        assert_eq!(clean.error.to_bits(), again.error.to_bits());
        assert_eq!(clean.energy.to_bits(), again.energy.to_bits());
    }
    // the re-evaluation healed the entry
    let p = EvalProblem::with_cache(&eval, RuleKind::Cip, Executor::serial(), cache);
    p.evaluate(&genome);
    assert_eq!(p.persist_stats(), (1, 0));
}

fn walk_entries(dir: &std::path::Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    for sub in std::fs::read_dir(dir).into_iter().flatten().flatten() {
        if sub.path().is_dir() {
            for f in std::fs::read_dir(sub.path()).into_iter().flatten().flatten() {
                if f.path().extension().is_some_and(|e| e == "json") {
                    out.push(f.path());
                }
            }
        }
    }
    out
}

/// The daemon and the CLI produce byte-identical tunes for the same job
/// — the scheduler, the per-benchmark evaluator reuse, and the thread
/// plan change scheduling, never values.
#[test]
fn serve_matches_cli_byte_identical() {
    let mut cfg = ServiceConfig::new();
    cfg.threads = 2;
    let svc = Service::start(cfg).unwrap();
    let id = svc
        .submit(spec(
            "determinism",
            JobKind::Tune {
                benchmark: "blackscholes".to_string(),
                rule: RuleKind::Cip,
                goal: TuneGoal::ErrorBudget(0.05),
                max_evals: 60,
            },
        ))
        .unwrap();
    let snap = svc.wait(id, Duration::from_secs(300)).unwrap();
    assert_eq!(snap.state, JobState::Done, "error: {:?}", snap.error);
    let (svc_genome, svc_obj) = match &snap.outputs[0] {
        ShardOutput::Tune(t) => (t.genome.clone(), t.objectives),
        other => panic!("expected a tune output, got {other:?}"),
    };
    svc.shutdown();

    // the CLI path: same benchmark registry entry, same tuner defaults
    let w = neat::bench_suite::by_name("blackscholes").unwrap();
    let eval = Evaluator::new(w, None);
    let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::new(2));
    let mut tc = TunerConfig::new(TuneGoal::ErrorBudget(0.05));
    tc.max_evals = 60;
    let cli = Tuner::new(tc).run(&problem);

    assert_eq!(svc_genome, cli.genome);
    assert_eq!(svc_obj.error.to_bits(), cli.objectives.error.to_bits());
    assert_eq!(svc_obj.energy.to_bits(), cli.objectives.energy.to_bits());
}

/// Resubmitting a completed job against the same cache directory — in a
/// *fresh daemon*, as after a restart — is answered entirely from the
/// content-addressed cache: `cache_hit` is true and the values are
/// bit-identical.
#[test]
fn cached_resubmit_after_restart_is_a_cache_hit() {
    let cache_dir = tmp("resubmit");
    let probe = || {
        spec(
            "resubmit",
            JobKind::Probe {
                benchmark: "blackscholes".to_string(),
                rule: RuleKind::Wp,
                genome: vec![11],
            },
        )
    };
    let run = |expect_hit: bool| {
        let mut cfg = ServiceConfig::new();
        cfg.threads = 2;
        cfg.cache_dir = Some(cache_dir.clone());
        let svc = Service::start(cfg).unwrap();
        let id = svc.submit(probe()).unwrap();
        let snap = svc.wait(id, Duration::from_secs(120)).unwrap();
        assert_eq!(snap.state, JobState::Done, "error: {:?}", snap.error);
        assert_eq!(
            snap.cache_hit(),
            expect_hit,
            "cache_hit: hits={} misses={}",
            snap.cache_hits,
            snap.cache_misses
        );
        svc.shutdown();
        match &snap.outputs[0] {
            ShardOutput::Probe { detail, .. } => *detail,
            other => panic!("expected a probe output, got {other:?}"),
        }
    };
    let cold = run(false);
    let warm = run(true);
    assert_eq!(cold.error.to_bits(), warm.error.to_bits());
    assert_eq!(cold.fpu_nec.to_bits(), warm.fpu_nec.to_bits());
    assert_eq!(cold.fpu_target_nec.to_bits(), warm.fpu_target_nec.to_bits());
}

/// Graceful shutdown parks still-queued jobs as artifacts; a fresh
/// daemon over the same run dir resumes and completes them.
#[test]
fn shutdown_parks_queued_jobs_and_resume_completes_them() {
    let run_dir = tmp("park");
    let mut cfg = ServiceConfig::new();
    cfg.threads = 1; // one runner: everything behind the first job queues
    cfg.run_dir = Some(run_dir.clone());
    let svc = Service::start(cfg.clone()).unwrap();
    // the runner grabs this slow job first...
    svc.submit(spec(
        "park",
        JobKind::Tune {
            benchmark: "blackscholes".to_string(),
            rule: RuleKind::Cip,
            goal: TuneGoal::ErrorBudget(0.05),
            max_evals: 40,
        },
    ))
    .unwrap();
    // ...so these three probes are still queued at shutdown
    for width in [6u32, 12, 18] {
        svc.submit(spec(
            "park",
            JobKind::Probe {
                benchmark: "blackscholes".to_string(),
                rule: RuleKind::Wp,
                genome: vec![width],
            },
        ))
        .unwrap();
    }
    let parked = svc.shutdown();
    assert!(
        !parked.is_empty(),
        "at least the later probes must still be queued at shutdown"
    );
    let artifacts = std::fs::read_dir(run_dir.join("parked")).unwrap().count();
    assert_eq!(artifacts, parked.len());

    // fresh daemon, same run dir: resume and finish the parked jobs
    let svc2 = Service::start(cfg).unwrap();
    let resumed = svc2.resume_parked().unwrap();
    assert_eq!(resumed, parked.len());
    assert_eq!(
        std::fs::read_dir(run_dir.join("parked")).unwrap().count(),
        0,
        "resume must consume the artifacts"
    );
    // resumed jobs get fresh ids starting at 1
    for id in 1..=resumed as u64 {
        let snap = svc2.wait(id, Duration::from_secs(120)).unwrap();
        assert_eq!(snap.state, JobState::Done, "job {id} error: {:?}", snap.error);
    }
    svc2.shutdown();
}

/// Two tenants sharing one runner both make progress and both appear in
/// the fairness accounting.
#[test]
fn both_tenants_accumulate_service() {
    let mut cfg = ServiceConfig::new();
    cfg.threads = 1;
    let svc = Service::start(cfg).unwrap();
    let mut ids = Vec::new();
    for i in 0..3u32 {
        for tenant in ["alpha", "beta"] {
            ids.push(
                svc.submit(spec(
                    tenant,
                    JobKind::Probe {
                        benchmark: "blackscholes".to_string(),
                        rule: RuleKind::Wp,
                        genome: vec![4 + i * 5],
                    },
                ))
                .unwrap(),
            );
        }
    }
    for id in ids {
        let snap = svc.wait(id, Duration::from_secs(120)).unwrap();
        assert_eq!(snap.state, JobState::Done, "job {id} error: {:?}", snap.error);
    }
    let served = svc.tenant_served();
    let get = |name: &str| {
        served.iter().find(|(n, _)| n == name).map(|(_, ms)| *ms).unwrap_or(0.0)
    };
    assert!(get("alpha") > 0.0, "alpha never served: {served:?}");
    assert!(get("beta") > 0.0, "beta never served: {served:?}");
    let stats = svc.stats_json();
    assert!(stats.contains("\"tenants\""), "stats missing tenants: {stats}");
    svc.shutdown();
}

fn http_request(addr: SocketAddr, request: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(request.as_bytes()).unwrap();
    let mut out = String::new();
    stream.read_to_string(&mut out).unwrap();
    out
}

/// End-to-end over a real socket: health check, job submission, status
/// polling to completion, stats, graceful shutdown.
#[test]
fn http_round_trip_submit_poll_shutdown() {
    let mut cfg = ServiceConfig::new();
    cfg.threads = 2;
    let svc = Arc::new(Service::start(cfg).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let svc2 = svc.clone();
    let server = std::thread::spawn(move || http::serve(&svc2, listener));

    let health = http_request(addr, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(health.contains("200 OK") && health.contains("{\"ok\":1}"), "{health}");

    let body = "{\"kind\": \"probe\", \"tenant\": \"curl\", \"benchmark\": \"blackscholes\", \
                \"rule\": \"wp\", \"genome\": \"12\"}";
    let resp = http_request(
        addr,
        &format!(
            "POST /jobs HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    );
    assert!(resp.contains("200 OK") && resp.contains("\"id\":"), "{resp}");
    let id: u64 = resp
        .split("\"id\":")
        .nth(1)
        .map(|s| s.chars().take_while(char::is_ascii_digit).collect::<String>())
        .and_then(|s| s.parse().ok())
        .expect("job id in response");

    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let status =
            http_request(addr, &format!("GET /jobs/{id} HTTP/1.1\r\nHost: t\r\n\r\n"));
        if status.contains("\"state\":\"done\"") {
            assert!(status.contains("\"kind\":\"probe\""), "{status}");
            break;
        }
        assert!(
            !status.contains("\"state\":\"failed\""),
            "job failed: {status}"
        );
        assert!(Instant::now() < deadline, "timed out polling; last: {status}");
        std::thread::sleep(Duration::from_millis(20));
    }

    let stats = http_request(addr, "GET /stats HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(
        stats.contains("\"shards_done\"") && stats.contains("\"queue_wait_ms\""),
        "{stats}"
    );
    let missing = http_request(addr, "GET /jobs/99999 HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(missing.contains("404"), "{missing}");
    let bad = http_request(
        addr,
        "POST /jobs HTTP/1.1\r\nHost: t\r\nContent-Length: 2\r\n\r\n{}",
    );
    assert!(bad.contains("400"), "empty spec must be rejected: {bad}");

    let down = http_request(addr, "POST /shutdown HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(down.contains("\"ok\":1"), "{down}");
    server.join().unwrap().unwrap();
    assert!(svc.is_shutdown());
}
