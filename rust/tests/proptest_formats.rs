//! Differential battery for the custom-format FPI family.
//!
//! The format quantizer (`neat::fpi::quantize32/64`) is pinned two ways:
//!
//! 1. Against an **independently written scalar softfloat reference**
//!    (this file's `ref_quantize64`): a fresh decompose/round/reassemble
//!    implementation that never shares the engine's normalization or
//!    carry handling. Round-to-nearest-even on exact halfway points,
//!    subnormal round trips, NaN/Inf propagation, and both overflow
//!    policies are checked over arbitrary bit patterns.
//! 2. Against the engine's own determinism contract: for every preset
//!    (bfloat16 / fp16 / TF32 / arbitrary points, with and without
//!    saturation and stochastic rounding), the slice kernels must be
//!    bit-identical to the scalar op sequence in values, counters, and
//!    trace bytes — in the default build that pins scalar vs block, and
//!    under `--features lanes` scalar vs the lane tier, so the CI
//!    feature matrix closes the scalar/block/lanes triangle.
//!
//! Stochastic rounding is additionally pinned as *schedule-free*: its
//! draw is a pure function of (seed, value bits), so archives produced
//! through the serial and multi-threaded executors are byte-identical,
//! while distinct seeds produce distinct rounding.

use std::collections::HashMap;
use std::io::Write;
use std::sync::{Arc, Mutex};

use neat::bench_suite;
use neat::coordinator::experiments::{explore_rule_with, Budget};
use neat::coordinator::{Evaluator, Executor, RuleKind};
use neat::engine::trace::TraceSink;
use neat::engine::FpContext;
use neat::fpi::format::sr_hash;
use neat::fpi::{
    quantize32, quantize64, CustomFormatFpi, FormatSpec, FpiLibrary, OpKind, Overflow,
    Precision, QuantParams, Rounding,
};
use neat::placement::Placement;
use neat::util::proptest_lite::{check, Config};
use neat::util::Pcg64;

fn cfg(cases: u64) -> Config {
    Config { cases, ..Default::default() }
}

// ---------------------------------------------------------------------
// The independent softfloat reference
// ---------------------------------------------------------------------

const MANT_MASK: u64 = (1 << 52) - 1;

/// 2^e as an exact `f64` (e in -1074..=1023), by bit construction.
fn pow2(e: i32) -> f64 {
    assert!((-1074..=1023).contains(&e), "pow2({e}) out of range");
    if e >= -1022 {
        f64::from_bits(((e + 1023) as u64) << 52)
    } else {
        f64::from_bits(1u64 << (e + 1074))
    }
}

/// Decompose a positive finite `f64` as `m · 2^ex`, `m` a nonzero
/// integer (not normalized — trailing zeros stay in `m`).
fn decompose(a: f64) -> (u64, i32) {
    let bits = a.to_bits();
    let ef = ((bits >> 52) & 0x7ff) as i32;
    let m = bits & MANT_MASK;
    if ef == 0 {
        (m, -1074)
    } else {
        (m | (1 << 52), ef - 1075)
    }
}

fn bitlen(n: u64) -> i32 {
    (64 - n.leading_zeros()) as i32
}

fn ref_overflow(neg: bool, q: &QuantParams) -> f64 {
    let r = match q.overflow {
        Overflow::Infinity => f64::INFINITY,
        // largest finite: an all-ones significand at the top exponent
        Overflow::Saturate => (pow2(q.sig as i32) - 1.0) * pow2(q.emax - q.sig as i32 + 1),
    };
    if neg {
        -r
    } else {
        r
    }
}

/// The reference quantizer: same grid semantics as
/// [`neat::fpi::quantize64`], implemented freshly. The value is split as
/// an un-normalized integer times a power of two, the discarded fraction
/// is compared against half (or against the stochastic threshold) with
/// plain shifts, and the result is reassembled by exact `f64`
/// multiplication — every step representable, so no double rounding.
fn ref_quantize64(x: f64, q: &QuantParams) -> f64 {
    if !x.is_finite() || x == 0.0 {
        return x;
    }
    let neg = x.is_sign_negative();
    let (m, ex) = decompose(x.abs());
    let e_val = ex + bitlen(m) - 1;
    let g = e_val.max(q.emin) - (q.sig as i32 - 1); // grid ulp exponent
    let d = g - ex; // discarded low bits
    if d <= 0 {
        if e_val > q.emax {
            return ref_overflow(neg, q);
        }
        return x;
    }
    let (n_lo, thresh, rne_up) = if d >= 64 {
        // the whole significand is below the grid point; m < 2^53 is
        // always under half the step, so RNE flushes to zero
        let t = if d - 64 >= 64 { 0 } else { m >> (d - 64) };
        (0u64, t, false)
    } else {
        let rem = m & ((1u64 << d) - 1);
        let half = 1u64 << (d - 1);
        let n_lo = m >> d;
        (n_lo, rem << (64 - d), rem > half || (rem == half && n_lo & 1 == 1))
    };
    let up = match q.rounding {
        Rounding::NearestEven => rne_up,
        Rounding::Stochastic { seed } => sr_hash(seed, x.to_bits()) < thresh,
    };
    let n = n_lo + up as u64;
    if n == 0 {
        return if neg { -0.0 } else { 0.0 };
    }
    if g + bitlen(n) - 1 > q.emax {
        return ref_overflow(neg, q);
    }
    let r = (n as f64) * pow2(g); // exact: n <= 2^53, product in range
    if neg {
        -r
    } else {
        r
    }
}

fn ref_quantize32(x: f32, q: &QuantParams) -> f32 {
    if !x.is_finite() {
        return x;
    }
    ref_quantize64(x as f64, q) as f32
}

/// An arbitrary lattice point with random policies; `sr_rate` of them
/// get seeded stochastic rounding.
fn gen_spec(rng: &mut Pcg64) -> FormatSpec {
    let mut s = FormatSpec::new(2 + rng.below(10) as u32, 2 + rng.below(52) as u32);
    if rng.below(2) == 1 {
        s = s.saturating();
    }
    if rng.below(3) == 0 {
        s = s.stochastic(rng.next_u64());
    }
    s
}

#[derive(Debug, Clone)]
struct BitsCase {
    spec: FormatSpec,
    bits: Vec<u64>,
}

#[test]
fn prop_quantize_matches_softfloat_reference_on_arbitrary_bits() {
    let gen = |rng: &mut Pcg64| BitsCase {
        spec: gen_spec(rng),
        bits: (0..64).map(|_| rng.next_u64()).collect(),
    };
    check("quantize == softfloat reference", cfg(256), gen, |c| {
        let (q64, q32) = (c.spec.params64(), c.spec.params32());
        c.bits.iter().all(|&b| {
            // arbitrary patterns include NaNs, infinities, zeros, and
            // subnormals — the reference must agree bit for bit
            let x = f64::from_bits(b);
            let y = f32::from_bits(b as u32);
            quantize64(x, &q64).to_bits() == ref_quantize64(x, &q64).to_bits()
                && quantize32(y, &q32).to_bits() == ref_quantize32(y, &q32).to_bits()
        })
    });
}

#[derive(Debug, Clone)]
struct TieCase {
    spec: FormatSpec,
    n: u64,
    g: i32,
    neg: bool,
}

#[test]
fn prop_exact_halfway_points_tie_to_even() {
    // x = (2n+1)·2^(g-1) sits exactly between grid neighbors n and n+1
    // at grid exponent g; RNE must land on the even one. sig <= 52 so
    // the tie itself is exactly representable.
    let gen = |rng: &mut Pcg64| {
        let mut spec = FormatSpec::new(2 + rng.below(10) as u32, 2 + rng.below(51) as u32);
        if rng.below(2) == 1 {
            spec = spec.saturating();
        }
        let q = spec.params64();
        let glo = q.emin - (q.sig as i32 - 1);
        let ghi = q.emax - q.sig as i32; // carry to 2^sig stays <= emax
        let g = glo + rng.below((ghi - glo + 1) as u64) as i32;
        let n = (1u64 << (q.sig - 1)) + rng.below(1u64 << (q.sig - 1));
        TieCase { spec, n, g, neg: rng.below(2) == 1 }
    };
    check("halfway ties to even", cfg(256), gen, |c| {
        let q = c.spec.params64();
        let x = (2 * c.n + 1) as f64 * pow2(c.g - 1);
        let even = if c.n % 2 == 0 { c.n } else { c.n + 1 };
        let want = even as f64 * pow2(c.g);
        let (x, want) = if c.neg { (-x, -want) } else { (x, want) };
        quantize64(x, &q).to_bits() == want.to_bits()
    });
}

#[derive(Debug, Clone)]
struct SubCase {
    spec: FormatSpec,
    k: u64,
}

#[test]
fn prop_subnormal_grid_round_trips_and_below_half_flushes() {
    // k·2^(emin-sig+1), k < 2^(sig-1), is on the format's subnormal
    // grid: it must survive quantization exactly in both rounding
    // modes. Half the smallest subnormal flushes to a signed zero
    // under RNE (tie to the even 0).
    let gen = |rng: &mut Pcg64| {
        let spec = gen_spec(rng);
        let k = 1 + rng.below((1u64 << (spec.params64().sig - 1).min(52)) - 1);
        SubCase { spec, k }
    };
    check("subnormal round trip", cfg(256), gen, |c| {
        let q = c.spec.params64();
        let step = pow2(q.emin - (q.sig as i32 - 1));
        let y = c.k as f64 * step;
        if quantize64(y, &q).to_bits() != y.to_bits()
            || quantize64(-y, &q).to_bits() != (-y).to_bits()
        {
            return false;
        }
        let rne = QuantParams { rounding: Rounding::NearestEven, ..q };
        quantize64(step / 2.0, &rne).to_bits() == 0.0f64.to_bits()
            && quantize64(-step / 2.0, &rne).to_bits() == (-0.0f64).to_bits()
    });
}

#[test]
fn nonfinite_propagation_and_overflow_policy_through_the_engine() {
    use neat::fpi::FpImplementation as _;
    // Infinity policy: the binary16 hardware rule
    let inf = CustomFormatFpi::new(FormatSpec::fp16());
    assert_eq!(inf.perform_f32(OpKind::Mul, 300.0, 300.0), f32::INFINITY);
    assert_eq!(inf.perform_f32(OpKind::Mul, -300.0, 300.0), f32::NEG_INFINITY);
    assert!(inf.perform_f32(OpKind::Add, f32::NAN, 1.0).is_nan());
    assert!(inf.perform_f64(OpKind::Sub, f64::INFINITY, f64::INFINITY).is_nan());
    assert_eq!(inf.perform_f64(OpKind::Add, f64::INFINITY, 1.0), f64::INFINITY);
    // Saturate policy: clamps to the largest finite (65504 for fp16)
    let sat = CustomFormatFpi::new(FormatSpec::fp16().saturating());
    assert_eq!(sat.perform_f32(OpKind::Mul, 300.0, 300.0), 65504.0);
    assert_eq!(sat.perform_f32(OpKind::Mul, -300.0, 300.0), -65504.0);
    // an infinity operand still passes through: saturation applies to
    // finite values that exceed the range, not to IEEE specials
    assert_eq!(sat.perform_f32(OpKind::Add, f32::INFINITY, 1.0), f32::INFINITY);
    assert!(sat.perform_f64(OpKind::Mul, f64::NAN, 2.0).is_nan());
}

#[derive(Debug, Clone)]
struct SrCase {
    spec: FormatSpec,
    xs: Vec<f64>,
}

#[test]
fn prop_stochastic_rounding_is_on_grid_value_keyed_and_idempotent() {
    let gen = |rng: &mut Pcg64| {
        let spec = FormatSpec::new(2 + rng.below(10) as u32, 2 + rng.below(52) as u32)
            .stochastic(rng.next_u64());
        SrCase { spec, xs: (0..32).map(|_| rng.normal() * 100.0).collect() }
    };
    check("SR on-grid + value-keyed", cfg(192), gen, |c| {
        let q = c.spec.params64();
        let rne = QuantParams { rounding: Rounding::NearestEven, ..q };
        c.xs.iter().all(|&x| {
            let y = quantize64(x, &q);
            // on the grid: the RNE quantizer is a no-op on SR output
            if quantize64(y, &rne).to_bits() != y.to_bits() {
                return false;
            }
            // within one grid step of the input (a neighbor, never a
            // skip) — unless the value overflowed past the format range
            if y.is_finite() {
                let (m, ex) = decompose(x.abs());
                let ulp = pow2((ex + bitlen(m) - 1).max(q.emin) - (q.sig as i32 - 1));
                if (y - x).abs() >= ulp {
                    return false;
                }
            }
            // value-keyed: a fresh params copy and a repeat call agree
            let again = quantize64(x, &c.spec.params64());
            // idempotent: re-quantizing draws nothing
            again.to_bits() == y.to_bits() && quantize64(y, &q).to_bits() == y.to_bits()
        })
    });
}

// ---------------------------------------------------------------------
// Engine identity: scalar ops vs slice kernels per preset
// ---------------------------------------------------------------------

/// The preset battery: industry layouts, a saturating arbitrary point,
/// and (per case) an optional stochastic-rounding overlay.
fn preset(rng: &mut Pcg64) -> FormatSpec {
    let presets = [
        FormatSpec::bfloat16(),
        FormatSpec::fp16(),
        FormatSpec::tf32(),
        FormatSpec::fp16().saturating(),
        FormatSpec::new(6, 7).saturating(),
    ];
    let mut spec = presets[rng.below(presets.len() as u64) as usize];
    if rng.below(3) == 0 {
        spec = spec.stochastic(rng.next_u64());
    }
    spec
}

#[derive(Debug, Clone)]
struct FmtScenario {
    spec: FormatSpec,
    op: OpKind,
    a: Vec<f32>,
    b: Vec<f32>,
}

fn gen_fmt_scenario(rng: &mut Pcg64) -> FmtScenario {
    let n = 1 + rng.below(40) as usize;
    FmtScenario {
        spec: preset(rng),
        op: OpKind::ALL[rng.below(4) as usize],
        a: (0..n).map(|_| (rng.normal() * 60.0) as f32).collect(),
        b: (0..n).map(|_| (rng.normal() * 60.0 + 0.5) as f32).collect(),
    }
}

fn fmt_ctx(spec: FormatSpec) -> FpContext {
    let mut lib = FpiLibrary::new();
    let id = lib.register(Arc::new(CustomFormatFpi::new(spec)));
    FpContext::new(lib, Placement::whole_program(id))
}

fn scalar_op32(c: &mut FpContext, op: OpKind, a: f32, b: f32) -> f32 {
    match op {
        OpKind::Add => c.add32(a, b),
        OpKind::Sub => c.sub32(a, b),
        OpKind::Mul => c.mul32(a, b),
        OpKind::Div => c.div32(a, b),
    }
}

fn scalar_op64(c: &mut FpContext, op: OpKind, a: f64, b: f64) -> f64 {
    match op {
        OpKind::Add => c.add64(a, b),
        OpKind::Sub => c.sub64(a, b),
        OpKind::Mul => c.mul64(a, b),
        OpKind::Div => c.div64(a, b),
    }
}

/// Shared in-memory trace buffer.
#[derive(Clone)]
struct Buf(Arc<Mutex<Vec<u8>>>);

impl Write for Buf {
    fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(b);
        Ok(b.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn prop_format_slice_kernels_match_scalar_in_values_counters_and_trace() {
    check("format slices == scalar", cfg(128), gen_fmt_scenario, |s| {
        let n = s.a.len();
        let a64: Vec<f64> = s.a.iter().map(|&x| x as f64).collect();
        let b64: Vec<f64> = s.b.iter().map(|&x| x as f64).collect();
        let mut rng = Pcg64::new(n as u64 ^ 0xF047);
        let idx: Vec<usize> = (0..n).map(|_| rng.below(n as u64) as usize).collect();
        let alpha = s.b[0];
        let (x0, y0) = (s.a[0], s.b[0]);
        for traced in [false, true] {
            let mut scalar = fmt_ctx(s.spec);
            let mut block = fmt_ctx(s.spec);
            let sbuf = Buf(Arc::new(Mutex::new(Vec::new())));
            let bbuf = Buf(Arc::new(Mutex::new(Vec::new())));
            if traced {
                scalar.set_trace(TraceSink::new(Box::new(sbuf.clone())));
                block.set_trace(TraceSink::new(Box::new(bbuf.clone())));
            }
            // scalar reference sequences
            let want: Vec<f32> =
                s.a.iter().zip(&s.b).map(|(&x, &y)| scalar_op32(&mut scalar, s.op, x, y)).collect();
            let mut w_sum = 0.0f32;
            for &x in &s.a {
                w_sum = scalar.add32(w_sum, x);
            }
            let mut w_dot = 0.0f32;
            for (&x, &y) in s.a.iter().zip(&s.b) {
                let p = scalar.mul32(x, y);
                w_dot = scalar.add32(w_dot, p);
            }
            let mut w_sq = 0.0f32;
            for (&x, &y) in s.a.iter().zip(&s.b) {
                let d = scalar.sub32(x, y);
                let m = scalar.mul32(d, d);
                w_sq = scalar.add32(w_sq, m);
            }
            let want64: Vec<f64> = a64
                .iter()
                .zip(&b64)
                .map(|(&x, &y)| scalar_op64(&mut scalar, s.op, x, y))
                .collect();
            let w_axpy: Vec<f32> = idx
                .iter()
                .zip(&s.b)
                .map(|(&j, &y)| {
                    let p = scalar.mul32(alpha, s.a[j]);
                    scalar.add32(p, y)
                })
                .collect();
            let w_gsq: Vec<f32> = idx
                .iter()
                .map(|&j| {
                    let dx = scalar.sub32(x0, s.a[j]);
                    let dy = scalar.sub32(y0, s.b[j]);
                    let xx = scalar.mul32(dx, dx);
                    let yy = scalar.mul32(dy, dy);
                    scalar.add32(xx, yy)
                })
                .collect();
            let mut w_gsum = 0.0f64;
            for &j in &idx {
                let v = scalar.load64(a64[j]);
                w_gsum = scalar.add64(w_gsum, v);
            }

            // the slice kernels
            let mut got = vec![0.0f32; n];
            block.map32_slice(s.op, &s.a[..], &s.b[..], &mut got);
            let g_sum = block.sum32_slice(&s.a);
            let g_dot = block.dot32_slice(&s.a, &s.b);
            let g_sq = block.sqdist32_slice(&s.a, &s.b);
            let mut got64 = vec![0.0f64; n];
            block.map64_slice(s.op, &a64[..], &b64[..], &mut got64);
            let mut g_axpy = vec![0.0f32; n];
            block.gather_axpy32_slice(alpha, &s.a, &idx, &s.b, &mut g_axpy);
            let mut g_gsq = vec![0.0f32; n];
            block.gather_sqdist2d32_slice(x0, y0, &s.a, &s.b, &idx, &mut g_gsq);
            let g_gsum = block.gather_sum64_slice(&a64, &idx);

            let ok = want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits())
                && w_sum.to_bits() == g_sum.to_bits()
                && w_dot.to_bits() == g_dot.to_bits()
                && w_sq.to_bits() == g_sq.to_bits()
                && want64.iter().zip(&got64).all(|(w, g)| w.to_bits() == g.to_bits())
                && w_axpy.iter().zip(&g_axpy).all(|(w, g)| w.to_bits() == g.to_bits())
                && w_gsq.iter().zip(&g_gsq).all(|(w, g)| w.to_bits() == g.to_bits())
                && w_gsum.to_bits() == g_gsum.to_bits()
                && *sbuf.0.lock().unwrap() == *bbuf.0.lock().unwrap()
                && scalar.counters() == block.counters();
            if !ok {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_format_boundary_lengths_pin_lane_remainder_tails() {
    // Empty, singleton, one-under/at/over each lane width, and a ragged
    // multiple — under `--features lanes` these hit the block/remainder
    // split of the format kernels; without it, the scalar loop. Both
    // must match the scalar op sequence bit for bit.
    use neat::engine::{LANES32, LANES64};
    let lens =
        [0usize, 1, LANES32 - 1, LANES32, LANES32 + 1, 2 * LANES32 + 3, LANES64 + 1];
    check("format boundary lengths == scalar", cfg(48), gen_fmt_scenario, |s| {
        for &n in &lens {
            let a: Vec<f32> = s.a.iter().copied().cycle().take(n).collect();
            let b: Vec<f32> = s.b.iter().copied().cycle().take(n).collect();
            let mut scalar = fmt_ctx(s.spec);
            let mut block = fmt_ctx(s.spec);
            let want: Vec<f32> =
                a.iter().zip(&b).map(|(&x, &y)| scalar_op32(&mut scalar, s.op, x, y)).collect();
            let mut w_dot = 0.0f32;
            for (&x, &y) in a.iter().zip(&b) {
                let p = scalar.mul32(x, y);
                w_dot = scalar.add32(w_dot, p);
            }
            let mut got = vec![0.0f32; n];
            block.map32_slice(s.op, &a[..], &b[..], &mut got);
            let g_dot = block.dot32_slice(&a, &b);
            if !want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits())
                || w_dot.to_bits() != g_dot.to_bits()
                || scalar.counters() != block.counters()
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn format_fpis_compose_with_cip_and_fcs_placements() {
    // A format FPI mapped to one function under CIP / inherited through
    // the call stack under FCS: scalar vs slice identity inside the
    // mapped frames, exactness outside them.
    let spec = FormatSpec::bfloat16().stochastic(21);
    let mut rng = Pcg64::new(0xC1F5);
    let a: Vec<f32> = (0..37).map(|_| (rng.normal() * 25.0) as f32).collect();
    let b: Vec<f32> = (0..37).map(|_| (rng.normal() * 25.0 + 1.0) as f32).collect();
    for call_stack in [false, true] {
        let build = || {
            let mut lib = FpiLibrary::new();
            let id = lib.register(Arc::new(CustomFormatFpi::new(spec)));
            let mut map = HashMap::new();
            map.insert("hot".to_string(), id);
            let p = if call_stack {
                Placement::call_stack(map)
            } else {
                Placement::current_function(map)
            };
            let mut ctx = FpContext::new(lib, p);
            let hot = ctx.register("hot");
            let cold = ctx.register("cold");
            (ctx, hot, cold)
        };
        let (mut scalar, s_hot, s_cold) = build();
        let (mut block, b_hot, b_cold) = build();
        let want: Vec<f32> = scalar.call(s_hot, |c| {
            a.iter().zip(&b).map(|(&x, &y)| c.mul32(x, y)).collect()
        });
        let mut got = vec![0.0f32; a.len()];
        block.call(b_hot, |c| c.mul32_slice(&a, &b, &mut got));
        for i in 0..a.len() {
            assert_eq!(want[i].to_bits(), got[i].to_bits(), "mapped frame, lane {i}");
        }
        // outside the mapped function both engines are exact IEEE
        let w_cold = scalar.call(s_cold, |c| c.mul32(a[0], b[0]));
        let mut g_cold = [0.0f32];
        block.call(b_cold, |c| c.mul32_slice(&a[..1], &b[..1], &mut g_cold));
        assert_eq!(w_cold.to_bits(), (a[0] * b[0]).to_bits());
        assert_eq!(w_cold.to_bits(), g_cold[0].to_bits());
        assert_eq!(scalar.counters(), block.counters());
    }
}

// ---------------------------------------------------------------------
// Stochastic rounding is schedule-free end to end
// ---------------------------------------------------------------------

#[test]
fn sr_archives_are_byte_identical_serial_vs_parallel() {
    let menu =
        [FormatSpec::bfloat16().stochastic(0xA5), FormatSpec::new(6, 6).stochastic(0xA5)];
    let archive = |menu: &[FormatSpec], threads: usize| {
        let w = bench_suite::by_name("blackscholes").expect("blackscholes exists");
        let eval = Evaluator::with_formats(w, None, menu);
        let res =
            explore_rule_with(&eval, RuleKind::Wp, Budget::quick(), &Executor::new(threads));
        res.details
            .iter()
            .map(|(g, d)| {
                (g.clone(), d.error.to_bits(), d.fpu_nec.to_bits(), d.mem_nec.to_bits())
            })
            .collect::<Vec<_>>()
    };
    let serial = archive(&menu, 1);
    // scheduling can never change values: 4 worker threads produce the
    // byte-identical archive, stochastic rounding included
    assert_eq!(serial, archive(&menu, 4), "4-thread archive diverged from serial");
    // a distinct seed must actually round differently somewhere
    let other_menu =
        [FormatSpec::bfloat16().stochastic(0xB6), FormatSpec::new(6, 6).stochastic(0xB6)];
    let other = archive(&other_menu, 1);
    assert_eq!(serial.len(), other.len(), "ladders must have the same shape");
    assert!(
        serial.iter().zip(&other).any(|(a, b)| a.1 != b.1),
        "seeds 0xA5 and 0xB6 produced identical error bits on every rung"
    );
}

#[test]
fn sr_whole_program_runs_are_reproducible_across_contexts() {
    // Two independent contexts over the same seeded-SR placement must
    // produce bit-identical outputs and counters — the engine-level
    // statement of "per-run variation comes from the seed, not from
    // allocation order or scheduling".
    let spec = FormatSpec::tf32().stochastic(1234);
    let run = || {
        let mut ctx = fmt_ctx(spec);
        let mut rng = Pcg64::new(0x5EED);
        let mut acc = 0.0f32;
        for _ in 0..500 {
            let x = (rng.normal() * 10.0) as f32;
            let p = ctx.mul32(acc, 1.0001);
            acc = ctx.add32(p, x);
        }
        let agg = ctx.counters().aggregate();
        (acc.to_bits(), agg)
    };
    let (a, ca) = run();
    let (b, cb) = run();
    assert_eq!(a, b);
    assert_eq!(ca, cb);
    // Precision targets gate format FPIs exactly like truncation: under
    // a Double-only target the f32 path stays exact
    let mut gated = fmt_ctx(spec);
    gated.set_target(Precision::Double);
    assert_eq!(gated.mul32(1.1, 1.3).to_bits(), (1.1f32 * 1.3).to_bits());
}
