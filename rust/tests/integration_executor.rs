//! Integration tests for the batched parallel evaluation pipeline:
//! archive determinism (serial executor vs worker pool), genome memo
//! cache behavior, and pooled-context reuse across placements.

use neat::bench_suite::blackscholes::Blackscholes;
use neat::coordinator::experiments::{explore_rule_with, Budget};
use neat::coordinator::{EvalProblem, Evaluator, Executor, RuleKind};
use neat::explore::{random_search, Problem};

fn evaluator() -> Evaluator {
    Evaluator::new(Box::new(Blackscholes { options: 60 }), None)
}

/// The acceptance bar: for a fixed seed the parallel batched search
/// produces an archive identical to the serial path — same genomes,
/// bit-identical objective values, same order.
#[test]
fn parallel_search_archive_identical_to_serial() {
    let eval = evaluator();
    let serial = explore_rule_with(&eval, RuleKind::Cip, Budget::quick(), &Executor::serial());
    let parallel = explore_rule_with(&eval, RuleKind::Cip, Budget::quick(), &Executor::new(4));
    assert_eq!(serial.details.len(), parallel.details.len());
    for ((ga, da), (gb, db)) in serial.details.iter().zip(&parallel.details) {
        assert_eq!(ga, gb, "genome order must match");
        assert_eq!(da.error.to_bits(), db.error.to_bits());
        assert_eq!(da.fpu_nec.to_bits(), db.fpu_nec.to_bits());
        assert_eq!(da.mem_nec.to_bits(), db.mem_nec.to_bits());
        assert_eq!(da.fpu_target_nec.to_bits(), db.fpu_target_nec.to_bits());
    }
}

#[test]
fn wp_sweep_identical_serial_vs_parallel() {
    let eval = evaluator();
    let serial = explore_rule_with(&eval, RuleKind::Wp, Budget::quick(), &Executor::serial());
    let parallel = explore_rule_with(&eval, RuleKind::Wp, Budget::quick(), &Executor::new(3));
    assert_eq!(serial.details.len(), 24);
    for ((ga, da), (gb, db)) in serial.details.iter().zip(&parallel.details) {
        assert_eq!(ga, gb);
        assert_eq!(da.fpu_nec.to_bits(), db.fpu_nec.to_bits());
    }
}

#[test]
fn random_search_batches_identically() {
    let eval = evaluator();
    let ps = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
    let pp = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::new(3));
    let a = random_search(&ps, 20, 7);
    let b = random_search(&pp, 20, 7);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.genome, y.genome);
        assert_eq!(x.objectives, y.objectives);
    }
}

/// Duplicate genomes are answered from the memo cache, and every call —
/// hit or miss — still lands in the evaluation log.
#[test]
fn duplicate_genomes_hit_the_cache() {
    let eval = evaluator();
    let p = EvalProblem::with_executor(&eval, RuleKind::Cip, Executor::serial());
    let g = vec![12u32; p.genome_len()];
    let o1 = p.evaluate(&g);
    let o2 = p.evaluate(&g);
    assert_eq!(o1, o2);
    assert_eq!(p.cache_stats(), (1, 1), "second call must be a hit");

    // a batch with an internal duplicate and a cached genome: one new
    // unique execution, two answered from cache/dedup
    let h = vec![8u32; p.genome_len()];
    let batch = vec![h.clone(), h.clone(), g.clone()];
    let objs = p.evaluate_batch(&batch);
    assert_eq!(objs[0], objs[1]);
    let (hits, misses) = p.cache_stats();
    assert_eq!(misses, 2, "only two unique genomes ever executed");
    assert_eq!(hits, 3);
    assert_eq!(p.take_details().len(), 5, "all five calls recorded");
}

/// The serial executor reuses one pooled context via `set_placement`
/// across every task in a batch; results must match isolated
/// evaluations with fresh contexts (no stale resolution-cache leaks
/// across placements).
#[test]
fn pooled_context_reuse_matches_fresh_contexts() {
    let eval = evaluator();
    let genomes = vec![vec![24u32], vec![2u32], vec![24u32], vec![9u32]];
    let batch = eval.evaluate_train_batch(RuleKind::Wp, &genomes, &Executor::serial());
    for (g, d) in genomes.iter().zip(&batch) {
        let solo = eval.evaluate_train(RuleKind::Wp, g);
        assert_eq!(d.error.to_bits(), solo.error.to_bits());
        assert_eq!(d.fpu_nec.to_bits(), solo.fpu_nec.to_bits());
        assert_eq!(d.mem_nec.to_bits(), solo.mem_nec.to_bits());
    }
    // sanity: a stale 24-bit cache entry leaking into the 2-bit run
    // would erase the energy gap
    assert!(batch[1].fpu_nec < batch[0].fpu_nec);
}
