//! Property tests pinning the vectorized §III-C bit accounting to the
//! scalar rule. The lane tier counts used mantissa bits per block
//! (`used_bits_block*` — branch-free popcount-identity trailing zeros)
//! and applies truncate masks through a branchless blend
//! (`apply_mask_block*`); both must be bit-for-bit the scalar
//! `used_bits_*` / `apply_mask_*` on every lane, including the
//! adversarial corners (zero mantissa, dense mantissa, subnormals,
//! NaN/Inf, negative zero). These are pure `fpi` functions, so the
//! battery runs identically in every feature cell — no `lanes` gate.

use neat::fpi::{
    apply_mask_block32, apply_mask_block64, apply_mask_f32, apply_mask_f64, trunc_mask_f32,
    trunc_mask_f64, used_bits_block32, used_bits_block64, used_bits_f32, used_bits_f64,
    used_bits_lanes32, used_bits_lanes64,
};
use neat::util::proptest_lite::{check, Config};
use neat::util::Pcg64;

fn cfg(cases: u64) -> Config {
    Config { cases, ..Default::default() }
}

/// One adversarial f32 bit pattern: arbitrary bits plus forced visits
/// to every §III-C corner class.
fn adv32(rng: &mut Pcg64) -> f32 {
    let bits = match rng.below(8) {
        0 => rng.next_u64() as u32,                          // arbitrary
        1 => (rng.next_u64() as u32) & 0xff80_0000,          // zero mantissa
        2 => ((rng.next_u64() as u32) & 0xff80_0000) | 0x007f_ffff, // dense mantissa
        3 => (rng.next_u64() as u32) & 0x807f_ffff,          // subnormal / ±0
        4 => 0x7f80_0000 | ((rng.next_u64() as u32) & 0x807f_ffff), // NaN / Inf
        5 => 0x8000_0000,                                    // negative zero
        6 => 0x7f80_0000 | ((rng.next_u64() & 1) as u32) << 31, // ±Inf
        _ => 1 + (rng.next_u64() as u32 & 0xff),             // smallest subnormals
    };
    f32::from_bits(bits)
}

fn adv64(rng: &mut Pcg64) -> f64 {
    let bits = match rng.below(8) {
        0 => rng.next_u64(),
        1 => rng.next_u64() & 0xfff0_0000_0000_0000,
        2 => (rng.next_u64() & 0xfff0_0000_0000_0000) | 0x000f_ffff_ffff_ffff,
        3 => rng.next_u64() & 0x800f_ffff_ffff_ffff,
        4 => 0x7ff0_0000_0000_0000 | (rng.next_u64() & 0x800f_ffff_ffff_ffff),
        5 => 0x8000_0000_0000_0000,
        6 => 0x7ff0_0000_0000_0000 | (rng.next_u64() & 1) << 63,
        _ => 1 + (rng.next_u64() & 0xffff),
    };
    f64::from_bits(bits)
}

#[test]
fn block_used_bits_match_scalar_per_lane_f32() {
    check(
        "used_bits_block32 == Σ used_bits_f32",
        cfg(512),
        |rng| {
            let mut xs = [0.0f32; 8];
            for x in &mut xs {
                *x = adv32(rng);
            }
            xs
        },
        |xs| {
            let lanes = used_bits_lanes32(xs);
            let per_lane_ok = (0..8).all(|j| lanes[j] == used_bits_f32(xs[j]));
            let sum: u32 = xs.iter().map(|&x| used_bits_f32(x)).sum();
            per_lane_ok && used_bits_block32(xs) == sum
        },
    );
}

#[test]
fn block_used_bits_match_scalar_per_lane_f64() {
    check(
        "used_bits_block64 == Σ used_bits_f64",
        cfg(512),
        |rng| {
            let mut xs = [0.0f64; 4];
            for x in &mut xs {
                *x = adv64(rng);
            }
            xs
        },
        |xs| {
            let lanes = used_bits_lanes64(xs);
            let per_lane_ok = (0..4).all(|j| lanes[j] == used_bits_f64(xs[j]));
            let sum: u32 = xs.iter().map(|&x| used_bits_f64(x)).sum();
            per_lane_ok && used_bits_block64(xs) == sum
        },
    );
}

#[test]
fn block_used_bits_generic_over_odd_lane_counts() {
    // The block forms are const-generic; the engine uses 8/4 but the
    // rule must hold at any width (incl. the scalar degenerate case).
    check(
        "used_bits_block* at L ∈ {1, 3, 5}",
        cfg(256),
        |rng| [adv32(rng), adv32(rng), adv32(rng), adv32(rng), adv32(rng)],
        |xs| {
            let one: [f32; 1] = [xs[0]];
            let three: [f32; 3] = [xs[0], xs[1], xs[2]];
            used_bits_block32(&one) == used_bits_f32(xs[0])
                && used_bits_block32(&three)
                    == three.iter().map(|&x| used_bits_f32(x)).sum::<u32>()
                && used_bits_block32(xs) == xs.iter().map(|&x| used_bits_f32(x)).sum::<u32>()
        },
    );
}

#[test]
fn branchless_mask_is_bit_identical_f32() {
    check(
        "apply_mask_block32 == apply_mask_f32 per lane",
        cfg(512),
        |rng| {
            let mut xs = [0.0f32; 8];
            for x in &mut xs {
                *x = adv32(rng);
            }
            let keep = 1 + rng.below(24) as u32;
            (xs, trunc_mask_f32(keep))
        },
        |(xs, mask)| {
            let blended = apply_mask_block32(xs, *mask);
            (0..8).all(|j| blended[j].to_bits() == apply_mask_f32(xs[j], *mask).to_bits())
        },
    );
}

#[test]
fn branchless_mask_is_bit_identical_f64() {
    check(
        "apply_mask_block64 == apply_mask_f64 per lane",
        cfg(512),
        |rng| {
            let mut xs = [0.0f64; 4];
            for x in &mut xs {
                *x = adv64(rng);
            }
            let keep = 1 + rng.below(53) as u32;
            (xs, trunc_mask_f64(keep))
        },
        |(xs, mask)| {
            let blended = apply_mask_block64(xs, *mask);
            (0..4).all(|j| blended[j].to_bits() == apply_mask_f64(xs[j], *mask).to_bits())
        },
    );
}

#[test]
fn branchless_mask_on_arbitrary_bit_patterns() {
    // Raw u32/u64 reinterpretations — incl. NaN payloads the blend must
    // pass through untouched (bit equality, not value equality).
    check(
        "blend == branch on raw bit patterns",
        cfg(512),
        |rng| {
            let p32 = rng.next_u64() as u32;
            let p64 = rng.next_u64();
            let k32 = 1 + rng.below(24) as u32;
            let k64 = 1 + rng.below(53) as u32;
            (p32, p64, k32, k64)
        },
        |&(p32, p64, k32, k64)| {
            let (m32, m64) = (trunc_mask_f32(k32), trunc_mask_f64(k64));
            let x = f32::from_bits(p32);
            let y = f64::from_bits(p64);
            apply_mask_block32(&[x], m32)[0].to_bits() == apply_mask_f32(x, m32).to_bits()
                && apply_mask_block64(&[y], m64)[0].to_bits() == apply_mask_f64(y, m64).to_bits()
        },
    );
}

#[test]
fn horizontal_add_headroom_is_bounded() {
    // The engine folds per-block u32 sums into u64 totals; the worst
    // case per block is full-width mantissas in every lane and three
    // operand blocks per FLOP. Pin the bound the overflow argument in
    // `engine/slice.rs` relies on.
    let dense32 = [f32::from_bits(0x3fff_ffff); 8]; // all 24 bits used
    let dense64 = [f64::from_bits(0x3fff_ffff_ffff_ffff); 4]; // all 53 bits
    assert_eq!(used_bits_block32(&dense32), 24 * 8);
    assert_eq!(used_bits_block64(&dense64), 53 * 4);
    assert_eq!(3 * used_bits_block32(&dense32), 576); // ≪ u32::MAX
    assert_eq!(3 * used_bits_block64(&dense64), 636); // ≪ u32::MAX
}
