//! Property tests pinning the block-mode contract: slice kernels change
//! scheduling, never values. For random slices, placements (WP / CIP /
//! FCS), truncation widths, and the perturb FPI (the dyn-dispatch
//! path), every slice kernel must be bit-identical to its scalar op
//! sequence in **values, counters, and trace content** — which is what
//! keeps archives produced above the engine byte-identical no matter
//! which API a workload uses.

use std::collections::HashMap;
use std::io::Write;
use std::sync::{Arc, Mutex};

use neat::engine::trace::TraceSink;
use neat::engine::{FpContext, FuncId};
use neat::fpi::perturb::{PerturbFpi, PerturbMode};
use neat::fpi::{FpiLibrary, OpKind, Precision};
use neat::placement::Placement;
use neat::util::proptest_lite::{check, Config};
use neat::util::Pcg64;

fn cfg(cases: u64) -> Config {
    Config { cases, ..Default::default() }
}

/// Scalar reference op through the public API.
fn scalar_op32(c: &mut FpContext, op: OpKind, a: f32, b: f32) -> f32 {
    match op {
        OpKind::Add => c.add32(a, b),
        OpKind::Sub => c.sub32(a, b),
        OpKind::Mul => c.mul32(a, b),
        OpKind::Div => c.div32(a, b),
    }
}

fn scalar_op64(c: &mut FpContext, op: OpKind, a: f64, b: f64) -> f64 {
    match op {
        OpKind::Add => c.add64(a, b),
        OpKind::Sub => c.sub64(a, b),
        OpKind::Mul => c.mul64(a, b),
        OpKind::Div => c.div64(a, b),
    }
}

/// One generated scenario: a placement (WP-truncate, WP-dyn-perturb,
/// CIP, FCS), a truncation width, an op, and operand data.
#[derive(Debug, Clone)]
struct Scenario {
    kind: u8,
    width: u32,
    op: OpKind,
    a: Vec<f32>,
    b: Vec<f32>,
}

fn gen_scenario(rng: &mut Pcg64) -> Scenario {
    let n = 1 + rng.below(40) as usize;
    let ops = OpKind::ALL;
    Scenario {
        kind: rng.below(4) as u8,
        width: 1 + rng.below(24) as u32,
        op: ops[rng.below(4) as usize],
        a: (0..n).map(|_| (rng.normal() * 60.0) as f32).collect(),
        b: (0..n).map(|_| (rng.normal() * 60.0 + 0.5) as f32).collect(),
    }
}

/// Build the scenario's context; returns the context and the function
/// scope to run inside (`None` = toplevel).
fn build_ctx(s: &Scenario) -> (FpContext, Option<Vec<FuncId>>) {
    match s.kind {
        0 => {
            // WP truncation: the engine's inlined fast path
            let lib = FpiLibrary::truncation_family(Precision::Single);
            let p = Placement::whole_program(FpiLibrary::truncation_id(s.width));
            (FpContext::new(lib, p), None)
        }
        1 => {
            // WP perturb: the dyn-dispatch path
            let mut lib = FpiLibrary::new();
            let id = lib.register(Arc::new(PerturbFpi::new(s.width, PerturbMode::Result)));
            (FpContext::new(lib, Placement::whole_program(id)), None)
        }
        2 => {
            // CIP: FLOPs run inside a mapped function frame
            let lib = FpiLibrary::truncation_family(Precision::Single);
            let mut map = HashMap::new();
            map.insert("hot".to_string(), FpiLibrary::truncation_id(s.width));
            let mut ctx = FpContext::new(lib, Placement::current_function(map));
            let hot = ctx.register("hot");
            (ctx, Some(vec![hot]))
        }
        _ => {
            // FCS: an unmapped kernel inheriting a mapped caller
            let lib = FpiLibrary::truncation_family(Precision::Single);
            let mut map = HashMap::new();
            map.insert("caller".to_string(), FpiLibrary::truncation_id(s.width));
            let mut ctx = FpContext::new(lib, Placement::call_stack(map));
            let caller = ctx.register("caller");
            let kernel = ctx.register("kernel");
            (ctx, Some(vec![caller, kernel]))
        }
    }
}

/// Run `body` inside the scenario's frame stack.
fn in_scope<R>(ctx: &mut FpContext, frames: &Option<Vec<FuncId>>, body: impl FnOnce(&mut FpContext) -> R) -> R {
    match frames {
        None => body(ctx),
        Some(fs) => {
            for &f in fs {
                ctx.enter(f);
            }
            let r = body(ctx);
            for _ in fs {
                ctx.exit();
            }
            r
        }
    }
}

fn counters_match(a: &FpContext, b: &FpContext) -> bool {
    a.counters() == b.counters()
}

#[test]
fn prop_elementwise_slice_is_bit_identical_to_scalar() {
    check("map32_slice == scalar loop", cfg(192), gen_scenario, |s| {
        let (mut scalar, frames) = build_ctx(s);
        let (mut block, bframes) = build_ctx(s);
        let want: Vec<f32> = in_scope(&mut scalar, &frames, |c| {
            s.a.iter().zip(&s.b).map(|(&x, &y)| scalar_op32(c, s.op, x, y)).collect()
        });
        let mut got = vec![0.0f32; s.a.len()];
        in_scope(&mut block, &bframes, |c| {
            c.map32_slice(s.op, &s.a[..], &s.b[..], &mut got);
        });
        let values_ok =
            want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits());
        values_ok && counters_match(&scalar, &block)
    });
}

#[test]
fn prop_fused_kernels_are_bit_identical_to_scalar() {
    check("fused kernels == scalar sequences", cfg(128), gen_scenario, |s| {
        let (mut scalar, frames) = build_ctx(s);
        let (mut block, bframes) = build_ctx(s);
        // scalar reference: sum, dot, sqdist in sequence
        let (w_sum, w_dot, w_sq) = in_scope(&mut scalar, &frames, |c| {
            let mut sum = 0.0f32;
            for &x in &s.a {
                sum = c.add32(sum, x);
            }
            let mut dot = 0.0f32;
            for (&x, &y) in s.a.iter().zip(&s.b) {
                let p = c.mul32(x, y);
                dot = c.add32(dot, p);
            }
            let mut sq = 0.0f32;
            for (&x, &y) in s.a.iter().zip(&s.b) {
                let d = c.sub32(x, y);
                let m = c.mul32(d, d);
                sq = c.add32(sq, m);
            }
            (sum, dot, sq)
        });
        let (g_sum, g_dot, g_sq) = in_scope(&mut block, &bframes, |c| {
            (c.sum32_slice(&s.a), c.dot32_slice(&s.a, &s.b), c.sqdist32_slice(&s.a, &s.b))
        });
        w_sum.to_bits() == g_sum.to_bits()
            && w_dot.to_bits() == g_dot.to_bits()
            && w_sq.to_bits() == g_sq.to_bits()
            && counters_match(&scalar, &block)
    });
}

#[test]
fn prop_broadcast_and_mem_slices_match_scalar() {
    check("broadcast + mem traffic identical", cfg(128), gen_scenario, |s| {
        let (mut scalar, frames) = build_ctx(s);
        let (mut block, bframes) = build_ctx(s);
        let beta = s.b[0];
        let want: Vec<f32> = in_scope(&mut scalar, &frames, |c| {
            let out: Vec<f32> = s.a.iter().map(|&x| scalar_op32(c, s.op, x, beta)).collect();
            for &x in &s.a {
                c.load32(x);
            }
            for &x in &out {
                c.store32(x);
            }
            out
        });
        let mut got = vec![0.0f32; s.a.len()];
        in_scope(&mut block, &bframes, |c| {
            c.map32_slice(s.op, &s.a[..], beta, &mut got);
            c.load32_slice(&s.a);
            c.store32_slice(&got);
        });
        let values_ok =
            want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits());
        values_ok && counters_match(&scalar, &block)
    });
}

#[test]
fn prop_f64_slices_match_scalar_under_target_filter() {
    // double-precision kernels under a Single optimization target must
    // stay exact — the precomputed effective FPI has to honor the
    // target exactly like the scalar path does
    check("f64 slices + target filter", cfg(128), gen_scenario, |s| {
        let a64: Vec<f64> = s.a.iter().map(|&x| x as f64).collect();
        let b64: Vec<f64> = s.b.iter().map(|&x| x as f64).collect();
        for target in [None, Some(Precision::Single), Some(Precision::Double)] {
            let (mut scalar, frames) = build_ctx(s);
            let (mut block, bframes) = build_ctx(s);
            if let Some(t) = target {
                scalar.set_target(t);
                block.set_target(t);
            }
            let want: Vec<f64> = in_scope(&mut scalar, &frames, |c| {
                a64.iter().zip(&b64).map(|(&x, &y)| scalar_op64(c, s.op, x, y)).collect()
            });
            let mut got = vec![0.0f64; a64.len()];
            in_scope(&mut block, &bframes, |c| {
                c.map64_slice(s.op, &a64[..], &b64[..], &mut got);
            });
            if !want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()) {
                return false;
            }
            if !counters_match(&scalar, &block) {
                return false;
            }
        }
        true
    });
}

/// Shared in-memory trace buffer.
#[derive(Clone)]
struct Buf(Arc<Mutex<Vec<u8>>>);

impl Write for Buf {
    fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(b);
        Ok(b.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn prop_trace_content_is_identical_in_block_mode() {
    check("trace bytes identical", cfg(96), gen_scenario, |s| {
        let (mut scalar, frames) = build_ctx(s);
        let (mut block, bframes) = build_ctx(s);
        let sbuf = Buf(Arc::new(Mutex::new(Vec::new())));
        let bbuf = Buf(Arc::new(Mutex::new(Vec::new())));
        scalar.set_trace(TraceSink::new(Box::new(sbuf.clone())));
        block.set_trace(TraceSink::new(Box::new(bbuf.clone())));
        let want: Vec<f32> = in_scope(&mut scalar, &frames, |c| {
            s.a.iter().zip(&s.b).map(|(&x, &y)| scalar_op32(c, s.op, x, y)).collect()
        });
        let mut got = vec![0.0f32; s.a.len()];
        in_scope(&mut block, &bframes, |c| {
            c.map32_slice(s.op, &s.a[..], &s.b[..], &mut got);
        });
        let values_ok =
            want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits());
        values_ok
            && *sbuf.0.lock().unwrap() == *bbuf.0.lock().unwrap()
            && counters_match(&scalar, &block)
    });
}

#[test]
fn prop_boundary_lengths_pin_lane_remainder_tails() {
    // Lane-parallel builds (`--features lanes`) split every slice into
    // whole lane blocks plus a scalar remainder tail; without the
    // feature the loop is scalar throughout. Either way these lengths —
    // empty, single, one-under/at/over a lane, and a ragged multiple —
    // must stay bit-identical to the scalar op sequence in values and
    // counters for every placement kind.
    use neat::engine::{LANES32, LANES64};
    let lens =
        [0usize, 1, LANES32 - 1, LANES32, LANES32 + 1, 2 * LANES32 + 3, LANES64 + 1];
    check("boundary lengths == scalar", cfg(48), gen_scenario, |s| {
        for &n in &lens {
            let a: Vec<f32> = s.a.iter().copied().cycle().take(n).collect();
            let b: Vec<f32> = s.b.iter().copied().cycle().take(n).collect();
            let (mut scalar, frames) = build_ctx(s);
            let (mut block, bframes) = build_ctx(s);
            let (want, w_dot) = in_scope(&mut scalar, &frames, |c| {
                let out: Vec<f32> =
                    a.iter().zip(&b).map(|(&x, &y)| scalar_op32(c, s.op, x, y)).collect();
                let mut dot = 0.0f32;
                for (&x, &y) in a.iter().zip(&b) {
                    let p = c.mul32(x, y);
                    dot = c.add32(dot, p);
                }
                (out, dot)
            });
            let mut got = vec![0.0f32; n];
            let g_dot = in_scope(&mut block, &bframes, |c| {
                c.map32_slice(s.op, &a[..], &b[..], &mut got);
                c.dot32_slice(&a, &b)
            });
            if !want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()) {
                return false;
            }
            if w_dot.to_bits() != g_dot.to_bits() || !counters_match(&scalar, &block) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_gather_kernels_match_scalar_sequences() {
    // The gather kernels (neighbor-list / pixel-window shapes) against
    // their per-element scalar sequences: values, counters, and trace
    // bytes, for every placement kind.
    check("gather kernels == scalar", cfg(96), gen_scenario, |s| {
        let n = s.a.len();
        let mut rng = Pcg64::new(n as u64 ^ 0x6A77);
        let idx: Vec<usize> = (0..n).map(|_| rng.below(n as u64) as usize).collect();
        let alpha = s.b[0];
        let (x0, y0) = (s.a[0], s.b[0]);
        let a64: Vec<f64> = s.a.iter().map(|&x| x as f64).collect();

        // both trace states: untraced drives the monomorphized (and,
        // under `--features lanes`, lane-parallel) kernels; traced
        // drives the scalar fallback and must also match byte-for-byte
        for traced in [false, true] {
            let (mut scalar, frames) = build_ctx(s);
            let (mut block, bframes) = build_ctx(s);
            let sbuf = Buf(Arc::new(Mutex::new(Vec::new())));
            let bbuf = Buf(Arc::new(Mutex::new(Vec::new())));
            if traced {
                scalar.set_trace(TraceSink::new(Box::new(sbuf.clone())));
                block.set_trace(TraceSink::new(Box::new(bbuf.clone())));
            }

            let (w_axpy, w_sq, w_sum) = in_scope(&mut scalar, &frames, |c| {
                let axpy: Vec<f32> = idx
                    .iter()
                    .zip(&s.b)
                    .map(|(&j, &y)| {
                        let p = c.mul32(alpha, s.a[j]);
                        c.add32(p, y)
                    })
                    .collect();
                let sq: Vec<f32> = idx
                    .iter()
                    .map(|&j| {
                        let dx = c.sub32(x0, s.a[j]);
                        let dy = c.sub32(y0, s.b[j]);
                        let xx = c.mul32(dx, dx);
                        let yy = c.mul32(dy, dy);
                        c.add32(xx, yy)
                    })
                    .collect();
                let mut sum = 0.0f64;
                for &j in &idx {
                    let v = c.load64(a64[j]);
                    sum = c.add64(sum, v);
                }
                (axpy, sq, sum)
            });
            let mut g_axpy = vec![0.0f32; n];
            let mut g_sq = vec![0.0f32; n];
            let g_sum = in_scope(&mut block, &bframes, |c| {
                c.gather_axpy32_slice(alpha, &s.a, &idx, &s.b, &mut g_axpy);
                c.gather_sqdist2d32_slice(x0, y0, &s.a, &s.b, &idx, &mut g_sq);
                c.gather_sum64_slice(&a64, &idx)
            });
            let ok = w_axpy.iter().zip(&g_axpy).all(|(w, g)| w.to_bits() == g.to_bits())
                && w_sq.iter().zip(&g_sq).all(|(w, g)| w.to_bits() == g.to_bits())
                && w_sum.to_bits() == g_sum.to_bits()
                && *sbuf.0.lock().unwrap() == *bbuf.0.lock().unwrap()
                && counters_match(&scalar, &block);
            if !ok {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_sqrt_slice_kernels_match_columnwise_scalar_replicas() {
    // First-class coverage for the Newton sqrt slice kernels: the
    // packed column-major slice path against the corpus's scalar
    // column-major replica — values, counters, and trace bytes, for
    // every placement kind, with special cases (negative, zero, NaN)
    // mixed into the inputs.
    use neat::bench_suite::corpus::{sqrt32_columnwise, sqrt64_columnwise};
    use neat::bench_suite::{math32, math64};
    check("sqrt slices == columnwise scalar", cfg(96), gen_scenario, |s| {
        let mut xs32 = s.a.clone();
        // plant the specials the packing logic must route around
        xs32[0] = 0.0;
        if xs32.len() > 1 {
            xs32[1] = -xs32[1].abs() - 1.0;
        }
        if xs32.len() > 2 {
            xs32[2] = f32::NAN;
        }
        let xs64: Vec<f64> = xs32.iter().map(|&x| x as f64).collect();

        for traced in [false, true] {
            let (mut scalar, frames) = build_ctx(s);
            let (mut block, bframes) = build_ctx(s);
            let sbuf = Buf(Arc::new(Mutex::new(Vec::new())));
            let bbuf = Buf(Arc::new(Mutex::new(Vec::new())));
            if traced {
                scalar.set_trace(TraceSink::new(Box::new(sbuf.clone())));
                block.set_trace(TraceSink::new(Box::new(bbuf.clone())));
            }
            let mut want32 = vec![0.0f32; xs32.len()];
            let mut want64 = vec![0.0f64; xs64.len()];
            in_scope(&mut scalar, &frames, |c| {
                sqrt32_columnwise(c, &xs32, &mut want32);
                sqrt64_columnwise(c, &xs64, &mut want64);
            });
            let mut got32 = vec![0.0f32; xs32.len()];
            let mut got64 = vec![0.0f64; xs64.len()];
            in_scope(&mut block, &bframes, |c| {
                math32::sqrt32_slice(c, &xs32, &mut got32);
                math64::sqrt64_slice(c, &xs64, &mut got64);
            });
            let ok = want32.iter().zip(&got32).all(|(w, g)| w.to_bits() == g.to_bits())
                && want64.iter().zip(&got64).all(|(w, g)| w.to_bits() == g.to_bits())
                && *sbuf.0.lock().unwrap() == *bbuf.0.lock().unwrap()
                && counters_match(&scalar, &block);
            if !ok {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_gather_boundary_lengths_pin_remainder_tails() {
    // Adversarial index-list lengths for the gather kernels: empty,
    // singleton, one-under/at/over each lane width, and a ragged
    // multiple — every one must stay bit-identical to the scalar
    // sequences in values and counters for every placement kind.
    use neat::engine::{LANES32, LANES64};
    let lens = [
        0usize,
        1,
        LANES64 - 1,
        LANES64,
        LANES64 + 1,
        LANES32 - 1,
        LANES32,
        LANES32 + 1,
        2 * LANES32 + 3,
    ];
    check("gather boundary lengths == scalar", cfg(32), gen_scenario, |s| {
        let m = s.a.len();
        let alpha = s.b[0];
        let (x0, y0) = (s.a[0], s.b[0]);
        let a64: Vec<f64> = s.a.iter().map(|&x| x as f64).collect();
        for &n in &lens {
            let mut rng = Pcg64::new((n as u64) << 8 ^ m as u64 ^ 0x9A77);
            let idx: Vec<usize> = (0..n).map(|_| rng.below(m as u64) as usize).collect();
            let ys: Vec<f32> = (0..n).map(|_| (rng.normal() * 20.0) as f32).collect();
            let (mut scalar, frames) = build_ctx(s);
            let (mut block, bframes) = build_ctx(s);
            let (w_axpy, w_sq, w_sum) = in_scope(&mut scalar, &frames, |c| {
                let axpy: Vec<f32> = idx
                    .iter()
                    .zip(&ys)
                    .map(|(&j, &y)| {
                        let p = c.mul32(alpha, s.a[j]);
                        c.add32(p, y)
                    })
                    .collect();
                let sq: Vec<f32> = idx
                    .iter()
                    .map(|&j| {
                        let dx = c.sub32(x0, s.a[j]);
                        let dy = c.sub32(y0, s.b[j]);
                        let xx = c.mul32(dx, dx);
                        let yy = c.mul32(dy, dy);
                        c.add32(xx, yy)
                    })
                    .collect();
                let mut sum = 0.0f64;
                for &j in &idx {
                    let v = c.load64(a64[j]);
                    sum = c.add64(sum, v);
                }
                (axpy, sq, sum)
            });
            let mut g_axpy = vec![0.0f32; n];
            let mut g_sq = vec![0.0f32; n];
            let g_sum = in_scope(&mut block, &bframes, |c| {
                c.gather_axpy32_slice(alpha, &s.a, &idx, &ys, &mut g_axpy);
                c.gather_sqdist2d32_slice(x0, y0, &s.a, &s.b, &idx, &mut g_sq);
                c.gather_sum64_slice(&a64, &idx)
            });
            let ok = w_axpy.iter().zip(&g_axpy).all(|(w, g)| w.to_bits() == g.to_bits())
                && w_sq.iter().zip(&g_sq).all(|(w, g)| w.to_bits() == g.to_bits())
                && w_sum.to_bits() == g_sum.to_bits()
                && counters_match(&scalar, &block);
            if !ok {
                return false;
            }
        }
        true
    });
}

#[test]
fn pooled_context_block_mode_survives_set_placement_swaps() {
    // The executor's worker pool reuses one context across
    // configurations via set_placement; the precomputed effective FPI
    // must never leak across swaps.
    let lib = FpiLibrary::truncation_family(Precision::Single);
    let placements: Vec<Placement> = vec![
        Placement::whole_program(FpiLibrary::truncation_id(3)),
        Placement::whole_program_exact(),
        Placement::whole_program(FpiLibrary::truncation_id(17)),
        Placement::current_function(HashMap::from([(
            "hot".to_string(),
            FpiLibrary::truncation_id(2),
        )])),
        Placement::whole_program(FpiLibrary::truncation_id(9)),
    ];
    let mut rng = Pcg64::new(0xB10C);
    let a: Vec<f32> = (0..64).map(|_| (rng.normal() * 30.0) as f32).collect();
    let b: Vec<f32> = (0..64).map(|_| (rng.normal() * 30.0 + 1.0) as f32).collect();

    let mut pooled = FpContext::new(lib.clone(), placements[0].clone());
    let hot = pooled.register("hot");
    for p in &placements {
        pooled.set_placement(p.clone());
        // fresh context for the same placement = the reference run
        let mut fresh = FpContext::new(lib.clone(), p.clone());
        let fresh_hot = fresh.register("hot");
        let idx: Vec<usize> = (0..a.len()).map(|i| (i * 7) % a.len()).collect();
        let mut want = vec![0.0f32; a.len()];
        let mut w_gsq = vec![0.0f32; a.len()];
        fresh.call(fresh_hot, |c| c.mul32_slice(&a, &b, &mut want));
        let w_sum = fresh.call(fresh_hot, |c| c.sum32_slice(&a));
        fresh.call(fresh_hot, |c| {
            c.gather_sqdist2d32_slice(a[0], b[0], &a, &b, &idx, &mut w_gsq)
        });

        let mut got = vec![0.0f32; a.len()];
        let mut g_gsq = vec![0.0f32; a.len()];
        pooled.call(hot, |c| c.mul32_slice(&a, &b, &mut got));
        let g_sum = pooled.call(hot, |c| c.sum32_slice(&a));
        pooled.call(hot, |c| {
            c.gather_sqdist2d32_slice(a[0], b[0], &a, &b, &idx, &mut g_gsq)
        });

        for i in 0..a.len() {
            assert_eq!(want[i].to_bits(), got[i].to_bits(), "lane {i} after swap");
            assert_eq!(w_gsq[i].to_bits(), g_gsq[i].to_bits(), "gather lane {i} after swap");
        }
        assert_eq!(w_sum.to_bits(), g_sum.to_bits());
        assert_eq!(
            fresh.counters().aggregate(),
            pooled.counters().aggregate(),
            "counters diverged after set_placement"
        );
    }
}
