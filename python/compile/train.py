"""Train LeNet-5 on the synthetic digit corpus (build-time only).

Runs once inside `make artifacts`; the trained weights are serialized for
the Rust runtime. Training uses the pure-jnp model path at full precision
(bits = 24 everywhere) — precision exploration happens later, on the Rust
side, against the AOT-compiled inference module.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, model

TRAIN_N = 6000
EVAL_N = 1024
TRAIN_SEED = 1234
EVAL_SEED = 5678
BATCH = 64
EPOCHS = 8
LR = 0.05
MOMENTUM = 0.9


def _loss_fn(params, images, labels):
    # bits=None: the untruncated differentiable path (bitcast has no grad)
    logits = model.lenet_forward(params, images, None, use_pallas=False)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


@jax.jit
def _train_step(params, velocity, images, labels):
    loss, grads = jax.value_and_grad(_loss_fn)(params, images, labels)
    new_v = {k: MOMENTUM * velocity[k] - LR * grads[k] for k in params}
    new_p = {k: params[k] + new_v[k] for k in params}
    return new_p, new_v, loss


@jax.jit
def _accuracy(params, images, labels):
    logits = model.lenet_forward(params, images, None, use_pallas=False)
    return (jnp.argmax(logits, axis=1) == labels).mean()


def train(verbose=True):
    """Train and return (params, eval_images, eval_labels, eval_accuracy)."""
    train_x, train_y = dataset.generate(TRAIN_N, TRAIN_SEED)
    eval_x, eval_y = dataset.generate(EVAL_N, EVAL_SEED)

    params = model.init_params(jax.random.PRNGKey(0))
    velocity = {k: jnp.zeros_like(v) for k, v in params.items()}

    rng = np.random.default_rng(99)
    steps_per_epoch = TRAIN_N // BATCH
    t0 = time.time()
    for epoch in range(EPOCHS):
        order = rng.permutation(TRAIN_N)
        total = 0.0
        for step in range(steps_per_epoch):
            idx = order[step * BATCH : (step + 1) * BATCH]
            params, velocity, loss = _train_step(
                params, velocity, jnp.asarray(train_x[idx]), jnp.asarray(train_y[idx])
            )
            total += float(loss)
        acc = float(_accuracy(params, jnp.asarray(eval_x), jnp.asarray(eval_y)))
        if verbose:
            print(
                f"epoch {epoch + 1}/{EPOCHS}  loss={total / steps_per_epoch:.4f}  "
                f"eval_acc={acc:.4f}  ({time.time() - t0:.1f}s)"
            )

    return params, eval_x, eval_y, acc


if __name__ == "__main__":
    train()
