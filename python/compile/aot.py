"""AOT compile path: train LeNet-5, lower the Pallas-backed inference
function to HLO *text*, and serialize everything the Rust runtime needs.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (all under ``artifacts/``):
    lenet.hlo.txt       — inference module; params: images f32[B,32,32,1],
                          the 10 weight tensors (model.PARAM_SPECS order),
                          bits i32[8]; returns (logits f32[B,10],).
    lenet_weights.bin   — trained weights, flat little-endian f32 in
                          PARAM_SPECS order.
    eval_images.bin     — f32[EVAL_N, 32, 32, 1] held-out images.
    eval_labels.bin     — i32[EVAL_N] labels.
    lenet_meta.json     — shapes, batch size, slot names, per-slot FLOP
                          counts, baseline (full-precision) accuracy.

Python runs only here; the Rust binary is self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train

BATCH = 256


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_inference():
    """Lower the Pallas-path forward fn with weights as runtime params."""

    def infer(images, *flat_params_and_bits):
        flat_params = flat_params_and_bits[:-1]
        bits = flat_params_and_bits[-1]
        params = {
            name: p for (name, _), p in zip(model.PARAM_SPECS, flat_params)
        }
        return (model.lenet_forward(params, images, bits, use_pallas=True),)

    specs = [jax.ShapeDtypeStruct((BATCH, 32, 32, 1), jnp.float32)]
    specs += [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in model.PARAM_SPECS
    ]
    specs += [jax.ShapeDtypeStruct((model.NUM_SLOTS,), jnp.int32)]
    return jax.jit(infer).lower(*specs)


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--skip-train",
        action="store_true",
        help="reuse existing weights/eval data, regenerate only the HLO",
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    hlo_path = os.path.join(args.out_dir, "lenet.hlo.txt")
    weights_path = os.path.join(args.out_dir, "lenet_weights.bin")
    meta_path = os.path.join(args.out_dir, "lenet_meta.json")

    print("lowering inference module (pallas path)...")
    text = to_hlo_text(lower_inference())
    with open(hlo_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {hlo_path}")

    if args.skip_train and os.path.exists(weights_path):
        print("skipping training (weights exist)")
        return

    print("training LeNet-5 on synthetic digits...")
    params, eval_x, eval_y, acc = train.train()

    flat = np.concatenate(
        [np.asarray(params[name], np.float32).reshape(-1) for name, _ in model.PARAM_SPECS]
    )
    flat.astype("<f4").tofile(weights_path)
    eval_x.astype("<f4").tofile(os.path.join(args.out_dir, "eval_images.bin"))
    eval_y.astype("<i4").tofile(os.path.join(args.out_dir, "eval_labels.bin"))

    meta = {
        "batch": BATCH,
        "eval_n": train.EVAL_N,
        "slot_names": model.SLOT_NAMES,
        "param_specs": [[n, list(s)] for n, s in model.PARAM_SPECS],
        "flop_counts": model.flop_counts(batch=1),
        "baseline_accuracy": acc,
    }
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"baseline eval accuracy: {acc:.4f}")
    print("artifacts complete")


if __name__ == "__main__":
    main()
