"""NEAT build-time package: L1 Pallas kernels, L2 LeNet-5, AOT lowering.

x64 is enabled globally: the f64 truncation oracle (`kernels.ref`) needs
real double-precision arithmetic. All model tensors declare explicit
dtypes, so this does not change any artifact's types.
"""

import jax

jax.config.update("jax_enable_x64", True)
