"""L2: LeNet-5 forward pass with per-slot mantissa-bit truncation.

The paper's CNN case study (§V-H, Table IV/V) explores per-layer floating
point precision for LeNet-5. The model here is written so that the
*precision configuration is a runtime input*: the forward function takes
an i32[8] vector of mantissa widths (one per Table-V slot), meaning one
AOT-lowered HLO module serves every point the Rust NSGA-II explorer
visits — Python never runs on the search path.

Table-V slot layout (indices into ``bits``):
    0 conv1   1 pool1   2 conv2   3 pool2   4 conv3
    5 fc (both fully-connected layers)   6 tanh   7 internal (softmax &c.)

Two execution paths share this file:
  * ``lenet_forward(..., use_pallas=True)`` — conv/FC layers run through
    the L1 Pallas qmatmul kernel (im2col + tiled quantized matmul); this
    is what `aot.py` lowers to the artifact.
  * ``use_pallas=False`` — the same math via the pure-jnp oracle
    (`kernels.ref`); used for training (bits=24 everywhere) and as the
    pytest cross-check for the Pallas path.
"""

import jax
import jax.numpy as jnp

from .kernels import qmatmul as qmm
from .kernels import ref

NUM_SLOTS = 8
SLOT_NAMES = [
    "conv1", "pool1", "conv2", "pool2", "conv3", "fc", "tanh", "internal",
]

# (name, shape) of every parameter, in the flat serialization order used by
# artifacts/lenet_weights.bin and the Rust runtime.
PARAM_SPECS = [
    ("conv1_w", (5, 5, 1, 6)),
    ("conv1_b", (6,)),
    ("conv2_w", (5, 5, 6, 16)),
    ("conv2_b", (16,)),
    ("conv3_w", (5, 5, 16, 120)),
    ("conv3_b", (120,)),
    ("fc1_w", (120, 84)),
    ("fc1_b", (84,)),
    ("fc2_w", (84, 10)),
    ("fc2_b", (10,)),
]


def init_params(key):
    """Glorot-uniform initialisation for every PARAM_SPECS entry."""
    params = {}
    for name, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            if len(shape) == 4:
                fan_in = shape[0] * shape[1] * shape[2]
                fan_out = shape[0] * shape[1] * shape[3]
            else:
                fan_in, fan_out = shape
            limit = jnp.sqrt(6.0 / (fan_in + fan_out))
            params[name] = jax.random.uniform(
                sub, shape, jnp.float32, -limit, limit
            )
    return params


def _im2col(x, kh, kw):
    """Extract valid-padding (kh, kw) patches.

    x: f32[B, H, W, C] → f32[B, OH, OW, kh*kw*C], patch layout matching a
    HWIO kernel reshaped to (kh*kw*C, O).
    """
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches yields channel-major (C, kh, kw) feature
    # layout; transpose to (kh, kw, C) to match a reshaped HWIO kernel.
    b, oh, ow, _ = patches.shape
    c = x.shape[-1]
    patches = patches.reshape(b, oh, ow, c, kh, kw)
    patches = patches.transpose(0, 1, 2, 4, 5, 3)
    return patches.reshape(b, oh, ow, kh * kw * c)


def _t(x, bits):
    """Truncate unless ``bits`` is None (the differentiable training path).

    Truncation goes through ``bitcast_convert_type``, which has no
    gradient — so training must bypass it entirely rather than run with
    bits=24 (value-identical but gradient-dead).
    """
    return x if bits is None else ref.truncate_f32(x, bits)


def _matmul(x, w, bits_in, bits_out, use_pallas):
    if bits_in is None:
        return jnp.matmul(x, w, preferred_element_type=jnp.float32)
    if use_pallas:
        return qmm.qmatmul(x, w, bits_in, bits_out)
    return ref.qmatmul_ref(x, w, bits_in, bits_out)


def _conv(x, w, b, bits, use_pallas):
    """Quantized valid conv via im2col + qmatmul; bias add at out width."""
    kh, kw, c, o = w.shape
    cols = _im2col(x, kh, kw)
    bsz, oh, ow, k = cols.shape
    flat = cols.reshape(bsz * oh * ow, k)
    out = _matmul(flat, w.reshape(k, o), bits, bits, use_pallas)
    out = _t(out + b, bits)
    return out.reshape(bsz, oh, ow, o)


def _avg_pool(x, bits):
    """2x2 stride-2 average pooling, result truncated to ``bits``."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    out = x.mean(axis=(2, 4))
    return _t(out, bits)


def _tanh(x, bits):
    return _t(jnp.tanh(x), bits)


def lenet_forward(params, images, bits, use_pallas=True):
    """LeNet-5 forward pass under a per-slot precision configuration.

    images: f32[B, 32, 32, 1]; bits: i32[NUM_SLOTS] or None (training
    path: no truncation anywhere, keeping gradients alive). Returns logits
    f32[B, 10] (pre-softmax — argmax is taken on the Rust side; softmax
    is monotonic so the 'internal' slot truncation is applied to logits).
    """
    if bits is None:
        bits = [None] * NUM_SLOTS
    b_tanh, b_int = bits[6], bits[7]

    x = _t(images, bits[0])
    x = _conv(x, params["conv1_w"], params["conv1_b"], bits[0], use_pallas)
    x = _tanh(x, b_tanh)
    x = _avg_pool(x, bits[1])
    x = _conv(x, params["conv2_w"], params["conv2_b"], bits[2], use_pallas)
    x = _tanh(x, b_tanh)
    x = _avg_pool(x, bits[3])
    x = _conv(x, params["conv3_w"], params["conv3_b"], bits[4], use_pallas)
    x = _tanh(x, b_tanh)
    x = x.reshape(x.shape[0], 120)
    x = _matmul(x, params["fc1_w"], bits[5], bits[5], use_pallas)
    x = _t(x + params["fc1_b"], bits[5])
    x = _tanh(x, b_tanh)
    x = _matmul(x, params["fc2_w"], bits[5], bits[5], use_pallas)
    logits = _t(x + params["fc2_b"], bits[5])
    # 'internal' slot: the classifier head's bookkeeping FLOPs
    # (softmax normalisation &c.). Softmax is monotonic, so truncating the
    # logits is the value-relevant effect.
    return _t(logits, b_int)


FULL_PRECISION = jnp.full((NUM_SLOTS,), 24, jnp.int32)


def flop_counts(batch=1):
    """Analytical FLOP count per Table-V slot for one forward pass.

    Mirrors paper Fig 10 (FLOP breakdown per layer). Counts
    multiply-accumulate as 2 FLOPs, pooling as adds + one mul per window,
    tanh at its FLOP-equivalent polynomial cost (est. 8 FLOPs/elem),
    softmax as exp(8) + div(1) per class plus the normalising sum.
    """
    counts = {}
    counts["conv1"] = batch * 28 * 28 * 6 * (2 * 25 + 1)
    counts["pool1"] = batch * 14 * 14 * 6 * 4
    counts["conv2"] = batch * 10 * 10 * 16 * (2 * 25 * 6 + 1)
    counts["pool2"] = batch * 5 * 5 * 16 * 4
    counts["conv3"] = batch * 1 * 1 * 120 * (2 * 25 * 16 + 1)
    counts["fc"] = batch * (2 * 120 * 84 + 84 + 2 * 84 * 10 + 10)
    tanh_elems = batch * (28 * 28 * 6 + 10 * 10 * 16 + 120 + 84)
    counts["tanh"] = tanh_elems * 8
    counts["internal"] = batch * (10 * 9 + 10 + 10)
    return counts
