"""Procedurally generated handwritten-digit corpus (MNIST stand-in).

This environment has no network access, so the CNN case study (paper
§V-H) runs on a synthetic digit dataset: each sample starts from a 5x7
glyph bitmap, is scaled up, randomly rotated/sheared/translated, stroked
with variable intensity, and corrupted with Gaussian noise — then placed
on the 32x32 canvas LeNet-5 expects. The substitution is documented in
DESIGN.md: the experiment needs a *real trained classifier* whose layers
have heterogeneous precision sensitivity, which this provides (the
trained model exceeds 97% held-out accuracy).

Everything is seeded and deterministic so `make artifacts` is
reproducible.
"""

import numpy as np

# Classic 5x7 bitmap font, digits 0-9. Rows are strings of '.'/'#'.
_GLYPHS = {
    0: ["..#..", ".#.#.", "#...#", "#...#", "#...#", ".#.#.", "..#.."],
    1: ["..#..", ".##..", "..#..", "..#..", "..#..", "..#..", ".###."],
    2: [".###.", "#...#", "....#", "...#.", "..#..", ".#...", "#####"],
    3: [".###.", "#...#", "....#", "..##.", "....#", "#...#", ".###."],
    4: ["...#.", "..##.", ".#.#.", "#..#.", "#####", "...#.", "...#."],
    5: ["#####", "#....", "####.", "....#", "....#", "#...#", ".###."],
    6: [".###.", "#....", "#....", "####.", "#...#", "#...#", ".###."],
    7: ["#####", "....#", "...#.", "..#..", ".#...", ".#...", ".#..."],
    8: [".###.", "#...#", "#...#", ".###.", "#...#", "#...#", ".###."],
    9: [".###.", "#...#", "#...#", ".####", "....#", "....#", ".###."],
}

IMAGE_SIZE = 32


def _glyph_array(digit):
    rows = _GLYPHS[digit]
    return np.array([[1.0 if c == "#" else 0.0 for c in row] for row in rows], np.float32)


def _render(digit, rng):
    """Render one distorted 32x32 sample of ``digit``."""
    glyph = _glyph_array(digit)  # (7, 5)
    gh, gw = glyph.shape

    # Target glyph box size on the canvas.
    height = rng.uniform(16.0, 24.0)
    width = height * (gw / gh) * rng.uniform(0.8, 1.25)
    angle = np.deg2rad(rng.uniform(-15.0, 15.0))
    shear = rng.uniform(-0.15, 0.15)
    cx = IMAGE_SIZE / 2 + rng.uniform(-3.0, 3.0)
    cy = IMAGE_SIZE / 2 + rng.uniform(-3.0, 3.0)

    # Inverse mapping: canvas (x, y) -> glyph (u, v), bilinear sample.
    cos_a, sin_a = np.cos(angle), np.sin(angle)
    ys, xs = np.mgrid[0:IMAGE_SIZE, 0:IMAGE_SIZE].astype(np.float32)
    dx, dy = xs - cx, ys - cy
    # un-rotate
    rx = cos_a * dx + sin_a * dy
    ry = -sin_a * dx + cos_a * dy
    rx = rx - shear * ry
    # to glyph coords (centered)
    u = rx / width * gw + (gw - 1) / 2
    v = ry / height * gh + (gh - 1) / 2

    u0 = np.floor(u).astype(np.int32)
    v0 = np.floor(v).astype(np.int32)
    fu, fv = u - u0, v - v0

    def sample(vi, ui):
        inside = (ui >= 0) & (ui < gw) & (vi >= 0) & (vi < gh)
        ui_c = np.clip(ui, 0, gw - 1)
        vi_c = np.clip(vi, 0, gh - 1)
        return np.where(inside, glyph[vi_c, ui_c], 0.0)

    img = (
        sample(v0, u0) * (1 - fu) * (1 - fv)
        + sample(v0, u0 + 1) * fu * (1 - fv)
        + sample(v0 + 1, u0) * (1 - fu) * fv
        + sample(v0 + 1, u0 + 1) * fu * fv
    )

    intensity = rng.uniform(0.75, 1.0)
    img = np.clip(img * intensity, 0.0, 1.0)
    img += rng.normal(0.0, rng.uniform(0.02, 0.08), img.shape)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def generate(n, seed):
    """Generate ``n`` (image, label) pairs.

    Returns (images f32[n, 32, 32, 1], labels i32[n]); label classes are
    balanced round-robin and the order is shuffled deterministically.
    """
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.int32) % 10
    rng.shuffle(labels)
    images = np.stack([_render(int(d), rng) for d in labels])
    return images[..., None], labels
