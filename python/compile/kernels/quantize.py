"""Element-wise mantissa-truncation Pallas kernel.

This is the FPI (floating point implementation) primitive of the paper,
expressed for TPU-class hardware: instead of hooking every scalar SSE
instruction (the Pin mechanism on x86), truncation is applied as a
vectorised mask over whole VMEM blocks — see DESIGN.md
§Hardware-Adaptation.

The kernel is lowered with ``interpret=True`` so it becomes plain HLO and
runs on the CPU PJRT client (real-TPU Mosaic lowering is compile-only in
this environment).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Block shape for the element-wise pass. 512*128 f32 = 256 KiB per block,
# comfortably inside a TPU core's ~16 MiB VMEM with double-buffering.
BLOCK_ROWS = 512
BLOCK_COLS = 128


def _quantize_kernel(bits_ref, x_ref, o_ref):
    """Truncate a VMEM block of f32 to ``bits_ref[0]`` mantissa bits."""
    keep = bits_ref[0]
    zeroed = jnp.clip(ref.F32_MANTISSA_BITS - keep, 0, 23).astype(jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF) << zeroed
    x = x_ref[...]
    raw = jax.lax.bitcast_convert_type(x, jnp.uint32)
    trunc = jax.lax.bitcast_convert_type(raw & mask, jnp.float32)
    o_ref[...] = jnp.where(jnp.isfinite(x), trunc, x)


@functools.partial(jax.jit, static_argnames=())
def quantize(x, keep_bits):
    """Truncate an arbitrarily-shaped f32 array to ``keep_bits`` mantissa bits.

    ``keep_bits`` is a runtime i32 scalar (traced), so a single lowered
    module serves every precision configuration the explorer visits.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = BLOCK_COLS
    rows = -(-n // cols)  # ceil
    pad_rows = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    padded = jnp.zeros((pad_rows * cols,), jnp.float32)
    padded = padded.at[:n].set(flat).reshape(pad_rows, cols)
    bits = jnp.asarray(keep_bits, jnp.int32).reshape(1)

    out = pl.pallas_call(
        _quantize_kernel,
        grid=(pad_rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # bits: tiny, replicated
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pad_rows, cols), jnp.float32),
        interpret=True,
    )(bits, padded)
    return out.reshape(-1)[:n].reshape(shape)
