"""Quantized (mantissa-truncated) matmul Pallas kernel.

The paper's compute hot-spot for the CNN case study (§V-H): every conv /
fully-connected layer in LeNet-5 is lowered to im2col + this kernel.
Operands are truncated to a per-layer mantissa width, the product is
accumulated wide (f32 — the MXU accumulator), and the result is truncated
to the output width.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid tiles the output into (BLOCK_M, BLOCK_N) MXU-aligned blocks,
  * the K dimension stays whole per block — LeNet K ≤ 400, so an
    (BLOCK_M, K) + (K, BLOCK_N) + (BLOCK_M, BLOCK_N) working set is
    ≤ ~0.5 MiB, far inside VMEM, letting Pallas double-buffer the
    HBM→VMEM streams,
  * truncation is a block-wide vector mask, not a per-scalar hook.

``interpret=True`` lowers the kernel to plain HLO so the artifact runs on
the CPU PJRT client (see /opt/xla-example/README.md).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Output-tile shape. On a real TPU the natural tile is MXU-aligned
# (128, 128) and BLOCK_M would be set accordingly; under interpret=True
# the grid lowers to a *sequential* HLO loop whose every trip
# dynamic-update-slices the full output buffer, so loop trips — not
# arithmetic — dominate. Stretching the M block from 4096 to 65536 cut
# the AOT artifact's per-batch latency 316 ms -> 147 ms (2.15x) on the
# CPU PJRT client (EXPERIMENTS.md §Perf L1/L2). The N block is sized to
# the lane-aligned output width — LeNet layer widths are 6..120, so a
# fixed 128-wide N block would be >20x padding waste.
BLOCK_M = 65536
LANE = 8  # N-padding granularity (TPU lane alignment)


def _qmatmul_kernel(bits_ref, x_ref, w_ref, o_ref):
    """One (BLOCK_M, BLOCK_N) output tile: truncate, matmul, truncate.

    ``bits_ref`` holds [bits_in, bits_out].
    """
    zeroed_in = jnp.clip(ref.F32_MANTISSA_BITS - bits_ref[0], 0, 23).astype(jnp.uint32)
    zeroed_out = jnp.clip(ref.F32_MANTISSA_BITS - bits_ref[1], 0, 23).astype(jnp.uint32)
    mask_in = jnp.uint32(0xFFFFFFFF) << zeroed_in
    mask_out = jnp.uint32(0xFFFFFFFF) << zeroed_out

    def trunc(v, mask):
        raw = jax.lax.bitcast_convert_type(v, jnp.uint32)
        t = jax.lax.bitcast_convert_type(raw & mask, jnp.float32)
        return jnp.where(jnp.isfinite(v), t, v)

    xq = trunc(x_ref[...], mask_in)
    wq = trunc(w_ref[...], mask_in)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    o_ref[...] = trunc(acc, mask_out)


def _pad_to(a, rows, cols):
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def qmatmul(x, w, bits_in, bits_out):
    """``truncate(truncate(x) @ truncate(w))`` with dynamic mantissa widths.

    x: f32[M, K], w: f32[K, N]; ``bits_in``/``bits_out``: traced i32
    scalars in [1, 24]. Returns f32[M, N].
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = min(BLOCK_M, -(-m // LANE) * LANE)
    pm = -(-m // bm) * bm
    pn = -(-n // LANE) * LANE  # N block spans the whole (padded) width
    xp = _pad_to(x, pm, k)
    wp = _pad_to(w, k, pn)
    bits = jnp.stack(
        [jnp.asarray(bits_in, jnp.int32), jnp.asarray(bits_out, jnp.int32)]
    )

    out = pl.pallas_call(
        _qmatmul_kernel,
        grid=(pm // bm,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # bits: tiny, replicated
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, pn), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, pn), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        interpret=True,
    )(bits, xp, wp)
    return out[:m, :n]
