"""Pure-jnp oracle implementations for the Pallas kernels.

These are the correctness references. The truncation primitives are exact
bit operations, so the Pallas quantize kernel must agree with them
bit-for-bit. Matmul accumulation order, however, is shape-dependent (the
kernel computes per-block gemms over padded tiles), so the qmatmul kernel
is compared against `qmatmul_ref` within a one-reassociation-ULP
tolerance scaled by the output truncation step.

The truncation semantics mirror the paper (§III-C) and the Rust FPI layer
(`rust/src/fpi/truncate.rs`):

* single precision carries 24 mantissa bits (1 implicit + 23 explicit);
  keeping ``k`` of them zeroes the low ``24 - k`` explicit bits,
* double precision carries 53 bits (1 implicit + 52 explicit); keeping
  ``k`` zeroes the low ``53 - k`` explicit bits,
* truncation is round-toward-zero (bit masking), exactly what a pruned
  FPU datapath produces,
* non-finite values (NaN/Inf) pass through untouched — masking the
  mantissa of a NaN could otherwise forge an Inf.
"""

import jax
import jax.numpy as jnp

F32_MANTISSA_BITS = 24  # incl. implicit leading 1
F64_MANTISSA_BITS = 53


def truncate_f32(x, keep_bits):
    """Keep ``keep_bits`` of the 24 f32 mantissa bits; zero the rest.

    ``keep_bits`` may be a traced i32 scalar (it is a runtime input of the
    AOT-lowered model, so the same executable serves every configuration).
    """
    x = jnp.asarray(x, jnp.float32)
    keep = jnp.asarray(keep_bits, jnp.int32)
    zeroed = jnp.clip(F32_MANTISSA_BITS - keep, 0, 23).astype(jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF) << zeroed
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    trunc = jax.lax.bitcast_convert_type(bits & mask, jnp.float32)
    return jnp.where(jnp.isfinite(x), trunc, x)


def truncate_f64(x, keep_bits):
    """Keep ``keep_bits`` of the 53 f64 mantissa bits; zero the rest."""
    x = jnp.asarray(x, jnp.float64)
    keep = jnp.asarray(keep_bits, jnp.int32)
    zeroed = jnp.clip(F64_MANTISSA_BITS - keep, 0, 52).astype(jnp.uint64)
    mask = jnp.uint64(0xFFFFFFFFFFFFFFFF) << zeroed
    bits = jax.lax.bitcast_convert_type(x, jnp.uint64)
    trunc = jax.lax.bitcast_convert_type(bits & mask, jnp.float64)
    return jnp.where(jnp.isfinite(x), trunc, x)


def qmatmul_ref(x, w, bits_in, bits_out):
    """Oracle for the quantized matmul kernel.

    Operands are truncated to ``bits_in`` mantissa bits, the product is
    accumulated in full f32 (the MXU-style wide accumulator), and the
    result is truncated to ``bits_out``.
    """
    xq = truncate_f32(x, bits_in)
    wq = truncate_f32(w, bits_in)
    acc = jnp.matmul(xq, wq, preferred_element_type=jnp.float32)
    return truncate_f32(acc, bits_out)


def quantize_ref(x, keep_bits):
    """Oracle for the element-wise quantize kernel (f32)."""
    return truncate_f32(x, keep_bits)
