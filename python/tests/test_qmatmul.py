"""Pallas qmatmul kernel vs the pure-jnp oracle (hypothesis sweeps).

The operand/result *truncation* inside the kernel is an exact bit
operation; the f32 *accumulation* order is shape-dependent (per-block
padded gemm vs one full gemm in the oracle), so comparisons allow a
reassociation tolerance: a few ULPs of the accumulator, widened by the
output truncation step 2^(1-bits_out) (a sub-ULP difference straddling a
mask boundary moves the truncated value by one step).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import qmatmul, ref

COMMON = dict(deadline=None, max_examples=20)


def assert_close(got, want, x, w, bits_out):
    """|got - want| <= accumulation slack + one output-truncation step.

    Reassociation error scales with the *accumulated magnitude*
    sum_k |x_ik w_kj| (cancellation can make |want| arbitrarily smaller),
    so the slack term uses the absolute-value product as its scale.
    """
    absprod = np.abs(x) @ np.abs(w)
    acc_slack = absprod * (8 * 2.0**-23)
    step = 2.0 ** (1 - bits_out) * np.maximum(np.abs(want), np.abs(got))
    tol = acc_slack + step + 1e-30
    assert np.all(np.abs(got - want) <= tol), np.abs(got - want).max()


@st.composite
def matmul_case(draw):
    m = draw(st.sampled_from([1, 3, 8, 37, 120, 300]))
    k = draw(st.sampled_from([1, 5, 25, 120, 400]))
    n = draw(st.sampled_from([1, 6, 10, 16, 84, 120]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    return x, w


@given(case=matmul_case(), bits_in=st.integers(1, 24), bits_out=st.integers(1, 24))
@settings(**COMMON)
def test_matches_oracle(case, bits_in, bits_out):
    x, w = case
    got = np.asarray(qmatmul.qmatmul(jnp.asarray(x), jnp.asarray(w), bits_in, bits_out))
    want = np.asarray(ref.qmatmul_ref(x, w, bits_in, bits_out))
    assert_close(got, want, x, w, bits_out)


@given(case=matmul_case())
@settings(**COMMON)
def test_full_precision_is_plain_matmul(case):
    x, w = case
    got = np.asarray(qmatmul.qmatmul(jnp.asarray(x), jnp.asarray(w), 24, 24))
    want = np.asarray(jnp.matmul(jnp.asarray(x), jnp.asarray(w)))
    assert_close(got, want, x, w, 24)


def test_operand_truncation_is_exact():
    """With a single-element K there is no accumulation: results must be
    bit-exact against the oracle for every bit width."""
    rng = np.random.default_rng(10)
    x = rng.standard_normal((33, 1)).astype(np.float32)
    w = rng.standard_normal((1, 7)).astype(np.float32)
    for bits in (1, 5, 13, 24):
        got = np.asarray(qmatmul.qmatmul(jnp.asarray(x), jnp.asarray(w), bits, bits))
        want = np.asarray(ref.qmatmul_ref(x, w, bits, bits))
        assert np.array_equal(got, want)


def test_blocking_is_invisible():
    """Results stay within tolerance when M spans many blocks."""
    rng = np.random.default_rng(11)
    x = rng.standard_normal((qmatmul.BLOCK_M * 2 + 17, 25)).astype(np.float32)
    w = rng.standard_normal((25, 6)).astype(np.float32)
    got = np.asarray(qmatmul.qmatmul(jnp.asarray(x), jnp.asarray(w), 9, 9))
    want = np.asarray(ref.qmatmul_ref(x, w, 9, 9))
    assert_close(got, want, x, w, 9)


def test_padding_rows_do_not_leak():
    """Zero padding must not perturb real output rows/cols."""
    rng = np.random.default_rng(12)
    x = rng.standard_normal((5, 7)).astype(np.float32)
    w = rng.standard_normal((7, 3)).astype(np.float32)
    small = np.asarray(qmatmul.qmatmul(jnp.asarray(x), jnp.asarray(w), 13, 13))
    assert small.shape == (5, 3)
    want = np.asarray(ref.qmatmul_ref(x, w, 13, 13))
    assert_close(small, want, x, w, 13)
