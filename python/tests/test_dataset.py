"""Tests for the synthetic digit corpus generator."""

import numpy as np

from compile import dataset


def test_shapes_and_dtypes():
    x, y = dataset.generate(50, seed=1)
    assert x.shape == (50, 32, 32, 1) and x.dtype == np.float32
    assert y.shape == (50,) and y.dtype == np.int32


def test_deterministic():
    a = dataset.generate(20, seed=7)
    b = dataset.generate(20, seed=7)
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])


def test_seeds_differ():
    a, _ = dataset.generate(20, seed=7)
    b, _ = dataset.generate(20, seed=8)
    assert not np.array_equal(a, b)


def test_value_range():
    x, _ = dataset.generate(100, seed=2)
    assert x.min() >= 0.0 and x.max() <= 1.0


def test_labels_balanced():
    _, y = dataset.generate(1000, seed=3)
    counts = np.bincount(y, minlength=10)
    assert counts.min() == counts.max() == 100


def test_images_have_signal():
    """Every image should contain actual glyph strokes, not just noise."""
    x, _ = dataset.generate(100, seed=4)
    bright = (x > 0.5).mean(axis=(1, 2, 3))
    assert (bright > 0.02).all(), "some images are blank"
    assert (bright < 0.6).all(), "some images are saturated"


def test_classes_distinguishable_by_template():
    """Nearest-mean-template classification should beat chance by a lot —
    a smoke test that the renderer actually encodes the label."""
    x, y = dataset.generate(600, seed=5)
    tx, ty = x[:500], y[:500]
    ex, ey = x[500:], y[500:]
    templates = np.stack([tx[ty == d].mean(axis=0) for d in range(10)])
    dists = ((ex[:, None] - templates[None]) ** 2).sum(axis=(2, 3, 4))
    pred = dists.argmin(axis=1)
    assert (pred == ey).mean() > 0.5
