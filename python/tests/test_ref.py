"""Unit tests for the truncation semantics (the FPI contract).

These pin the exact bit-level behaviour that the Rust FPI layer
(`rust/src/fpi/truncate.rs`) replicates — both sides must agree
bit-for-bit for the L1/L3 energy accounting to line up.
"""

import math
import struct

import numpy as np
import pytest

from compile.kernels import ref


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", x))[0]


def trunc32(x, k):
    return float(np.asarray(ref.truncate_f32(np.float32(x), k)))


def trunc64(x, k):
    return float(np.asarray(ref.truncate_f64(np.float64(x), k)))


class TestTruncateF32:
    def test_full_precision_is_identity(self):
        xs = np.array([1.0, -3.14159, 1e-30, 6.02e23], np.float32)
        out = np.asarray(ref.truncate_f32(xs, 24))
        assert np.array_equal(out, xs)

    def test_one_bit_keeps_only_implicit_leading_one(self):
        # keep=1 zeroes all 23 explicit bits: any x in [2^e, 2^{e+1}) -> 2^e
        assert trunc32(1.75, 1) == 1.0
        assert trunc32(7.99, 1) == 4.0
        assert trunc32(-1.75, 1) == -1.0

    def test_known_bit_pattern(self):
        # 1.5 = 1.1b; keeping 2 bits preserves it, keeping 1 floors to 1.0
        assert trunc32(1.5, 2) == 1.5
        assert trunc32(1.5, 1) == 1.0
        # 1.25 = 1.01b needs 3 bits
        assert trunc32(1.25, 3) == 1.25
        assert trunc32(1.25, 2) == 1.0

    def test_rounds_toward_zero(self):
        rng = np.random.default_rng(3)
        xs = (rng.standard_normal(500) * 100).astype(np.float32)
        for k in (1, 5, 12, 20):
            out = np.asarray(ref.truncate_f32(xs, k))
            assert np.all(np.abs(out) <= np.abs(xs))
            assert np.array_equal(np.signbit(out), np.signbit(xs))

    def test_relative_error_bound(self):
        # truncating to k bits gives relative error < 2^{1-k}
        rng = np.random.default_rng(4)
        xs = (rng.standard_normal(500) * 1e3).astype(np.float32)
        for k in (2, 8, 16, 23):
            out = np.asarray(ref.truncate_f32(xs, k))
            rel = np.abs(out - xs) / np.abs(xs)
            assert np.all(rel < 2.0 ** (1 - k))

    def test_idempotent(self):
        rng = np.random.default_rng(5)
        xs = rng.standard_normal(200).astype(np.float32)
        for k in (1, 7, 13):
            once = np.asarray(ref.truncate_f32(xs, k))
            twice = np.asarray(ref.truncate_f32(once, k))
            assert np.array_equal(once, twice)

    def test_nan_inf_passthrough(self):
        xs = np.array([np.nan, np.inf, -np.inf], np.float32)
        out = np.asarray(ref.truncate_f32(xs, 3))
        assert math.isnan(out[0])
        assert out[1] == np.inf and out[2] == -np.inf

    def test_zero_preserved(self):
        for k in (1, 12, 24):
            assert trunc32(0.0, k) == 0.0
            assert f32_bits(trunc32(-0.0, k)) == f32_bits(-0.0)

    def test_bits_clamped_out_of_range(self):
        # keep > 24 behaves as 24; keep < 1 behaves as 1 (clamp in kernel)
        assert trunc32(1.75, 30) == 1.75
        assert trunc32(1.75, 0) == 1.0


class TestTruncateF64:
    def test_full_precision_is_identity(self):
        xs = np.array([1.0, -3.141592653589793, 1e-300], np.float64)
        out = np.asarray(ref.truncate_f64(xs, 53))
        assert np.array_equal(out, xs)

    def test_one_bit(self):
        assert trunc64(1.999999, 1) == 1.0
        assert trunc64(-7.5, 1) == -4.0

    def test_relative_error_bound(self):
        rng = np.random.default_rng(6)
        xs = rng.standard_normal(500) * 1e6
        for k in (4, 24, 52):
            out = np.asarray(ref.truncate_f64(xs, k))
            rel = np.abs(out - xs) / np.abs(xs)
            assert np.all(rel < 2.0 ** (1 - k))

    def test_f32_embedding_consistency(self):
        # a f32 value truncated to k via the f64 path (k<=24) matches f32 path
        rng = np.random.default_rng(7)
        xs = rng.standard_normal(100).astype(np.float32)
        for k in (3, 11):
            via32 = np.asarray(ref.truncate_f32(xs, k), np.float64)
            via64 = np.asarray(ref.truncate_f64(xs.astype(np.float64), k))
            assert np.array_equal(via32, via64)


class TestQmatmulRef:
    def test_full_precision_is_plain_matmul(self):
        import jax.numpy as jnp
        rng = np.random.default_rng(8)
        x = rng.standard_normal((9, 17)).astype(np.float32)
        w = rng.standard_normal((17, 5)).astype(np.float32)
        got = np.asarray(ref.qmatmul_ref(x, w, 24, 24))
        # compare against the same backend's gemm (numpy's own gemm may
        # reassociate differently; the contract is "no truncation applied")
        want = np.asarray(jnp.matmul(jnp.asarray(x), jnp.asarray(w)))
        assert np.array_equal(got, want)

    def test_truncation_order(self):
        # operands truncated before the product, result after
        x = np.array([[1.75]], np.float32)
        w = np.array([[1.75]], np.float32)
        got = float(np.asarray(ref.qmatmul_ref(x, w, 1, 24))[0, 0])
        assert got == 1.0  # 1.0 * 1.0
        got2 = float(np.asarray(ref.qmatmul_ref(x, w, 24, 1))[0, 0])
        assert got2 == 2.0  # trunc(3.0625, 1 bit) = 2.0
