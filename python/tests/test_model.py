"""Tests for the LeNet-5 model: pallas path vs ref path, shapes, FLOPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dataset, model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(42))


@pytest.fixture(scope="module")
def batch():
    x, y = dataset.generate(16, seed=21)
    return jnp.asarray(x), jnp.asarray(y)


def test_logit_shape(params, batch):
    x, _ = batch
    out = model.lenet_forward(params, x, model.FULL_PRECISION, use_pallas=False)
    assert out.shape == (16, 10)


@pytest.mark.parametrize(
    "bits",
    [
        [24] * 8,
        [10, 23, 14, 4, 19, 4, 20, 17],  # paper Table V @1%
        [6, 16, 12, 9, 13, 1, 17, 11],  # paper Table V @10%
        [1] * 8,
    ],
)
def test_pallas_matches_ref(params, batch, bits):
    """Pallas and ref paths agree up to gemm reassociation ULPs (the
    truncation steps themselves are bit-exact; see test_qmatmul.py), and
    they must agree on every predicted class."""
    x, _ = batch
    bv = jnp.asarray(bits, jnp.int32)
    a = np.asarray(model.lenet_forward(params, x, bv, use_pallas=True))
    b = np.asarray(model.lenet_forward(params, x, bv, use_pallas=False))
    step = 2.0 ** (1 - min(bits))
    tol = np.maximum(np.abs(b), 1.0) * (1e-5 + step)
    assert np.all(np.abs(a - b) <= tol)
    assert np.array_equal(a.argmax(axis=1), b.argmax(axis=1))


def test_full_precision_matches_untruncated_conv(params, batch):
    """bits=24 must reproduce a plain lax.conv LeNet bit-for-bit."""
    x, _ = batch

    def plain(params, x):
        def conv(x, w, b):
            out = jax.lax.conv_general_dilated(
                x, w, (1, 1), "VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            return out + b

        x = conv(x, params["conv1_w"], params["conv1_b"])
        x = jnp.tanh(x)
        x = x.reshape(x.shape[0], 14, 2, 14, 2, 6).mean(axis=(2, 4))
        x = conv(x, params["conv2_w"], params["conv2_b"])
        x = jnp.tanh(x)
        x = x.reshape(x.shape[0], 5, 2, 5, 2, 16).mean(axis=(2, 4))
        x = conv(x, params["conv3_w"], params["conv3_b"])
        x = jnp.tanh(x)
        x = x.reshape(x.shape[0], 120)
        x = jnp.tanh(x @ params["fc1_w"] + params["fc1_b"])
        return x @ params["fc2_w"] + params["fc2_b"]

    got = model.lenet_forward(params, x, model.FULL_PRECISION, use_pallas=False)
    want = plain(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_lower_precision_changes_output(params, batch):
    x, _ = batch
    full = model.lenet_forward(params, x, model.FULL_PRECISION, use_pallas=False)
    low = model.lenet_forward(
        params, x, jnp.full((8,), 2, jnp.int32), use_pallas=False
    )
    assert not np.array_equal(np.asarray(full), np.asarray(low))


def test_param_specs_order_and_sizes():
    sizes = {n: int(np.prod(s)) for n, s in model.PARAM_SPECS}
    assert sizes["conv1_w"] == 150 and sizes["conv3_w"] == 48000
    assert sum(sizes.values()) == 61706  # LeNet-5 parameter count


def test_flop_counts_shape_of_fig10():
    """Paper Fig 10: conv layers dominate (>69% combined for conv+pool
    feature extraction); FLOPs shrink toward later conv layers' outputs."""
    c = model.flop_counts()
    total = sum(c.values())
    conv_share = (c["conv1"] + c["conv2"] + c["conv3"]) / total
    assert conv_share > 0.69
    assert c["internal"] < c["fc"] < c["conv2"]
