"""Pallas quantize kernel vs the pure-jnp oracle (hypothesis sweeps).

The kernel must agree with `ref.quantize_ref` exactly (truncation is a
deterministic bit operation, so comparison is bit equality, not
allclose).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize, ref

# Keep hypothesis example counts moderate: each example round-trips a
# pallas_call in interpret mode.
COMMON = dict(deadline=None, max_examples=25)


@st.composite
def f32_arrays(draw):
    shape = draw(
        st.sampled_from(
            [(1,), (7,), (128,), (3, 5), (65, 3), (2, 3, 4), (512,), (1, 1, 1, 9)]
        )
    )
    n = int(np.prod(shape))
    scale = draw(st.sampled_from([1e-20, 1e-3, 1.0, 1e4, 1e30]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n).reshape(shape) * scale).astype(np.float32)


@given(x=f32_arrays(), bits=st.integers(1, 24))
@settings(**COMMON)
def test_matches_oracle(x, bits):
    got = np.asarray(quantize.quantize(jnp.asarray(x), bits))
    want = np.asarray(ref.quantize_ref(x, bits))
    assert np.array_equal(got, want)


@given(x=f32_arrays())
@settings(**COMMON)
def test_full_precision_identity(x):
    got = np.asarray(quantize.quantize(jnp.asarray(x), 24))
    assert np.array_equal(got, x)


@given(x=f32_arrays(), bits=st.integers(1, 23))
@settings(**COMMON)
def test_magnitude_never_grows(x, bits):
    got = np.asarray(quantize.quantize(jnp.asarray(x), bits))
    assert np.all(np.abs(got) <= np.abs(x))


@given(bits=st.integers(1, 24))
@settings(**COMMON)
def test_nonfinite_passthrough(bits):
    x = np.array([np.nan, np.inf, -np.inf, 1.5], np.float32)
    got = np.asarray(quantize.quantize(jnp.asarray(x), bits))
    assert np.isnan(got[0]) and got[1] == np.inf and got[2] == -np.inf


@given(x=f32_arrays(), b1=st.integers(1, 24), b2=st.integers(1, 24))
@settings(**COMMON)
def test_coarser_truncation_dominates(x, b1, b2):
    """trunc_k2(trunc_k1(x)) == trunc_min(k1,k2)(x) — masks compose."""
    lo = min(b1, b2)
    a = quantize.quantize(quantize.quantize(jnp.asarray(x), b1), b2)
    b = quantize.quantize(jnp.asarray(x), lo)
    assert np.array_equal(np.asarray(a), np.asarray(b))
