//! End-to-end validation driver (DESIGN.md): run the complete NEAT
//! pipeline on real small workloads and report the paper's headline
//! metric — energy savings at 1% / 10% error budgets, per-function vs
//! whole-program — plus the CNN case study through the full
//! Rust→PJRT→JAX/Pallas artifact stack.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example e2e_neat

use neat::cnn::{CnnProblem, CnnRule};
use neat::coordinator::experiments::{explore_rule, Budget, THRESHOLDS};
use neat::coordinator::{Evaluator, RuleKind};
use neat::explore::{Nsga2, Nsga2Params};
use neat::runtime::{ArtifactPaths, LenetRuntime};
use neat::stats::{savings_at_thresholds, TradeoffPoint};

fn main() -> anyhow::Result<()> {
    let t_start = std::time::Instant::now();
    let budget = Budget::default();

    println!("== NEAT end-to-end validation ==\n");
    println!("[1/3] benchmark suite: WP vs CIP on three representative programs");
    let mut wp_savings_1 = Vec::new();
    let mut cip_savings_1 = Vec::new();
    let mut wp_savings_10 = Vec::new();
    let mut cip_savings_10 = Vec::new();
    for name in ["blackscholes", "fluidanimate", "particlefilter"] {
        let eval = Evaluator::new(neat::bench_suite::by_name(name).unwrap(), None);
        let wp = explore_rule(&eval, RuleKind::Wp, budget);
        let cip = explore_rule(&eval, RuleKind::Cip, budget);
        let wp_s = savings_at_thresholds(&wp.fpu_points(), &THRESHOLDS);
        let cip_s = savings_at_thresholds(&cip.fpu_points(), &THRESHOLDS);
        println!(
            "  {name:<16} WP @1%/@10%: {:>5.1}%/{:>5.1}%   CIP @1%/@10%: {:>5.1}%/{:>5.1}%",
            (1.0 - wp_s[0]) * 100.0,
            (1.0 - wp_s[2]) * 100.0,
            (1.0 - cip_s[0]) * 100.0,
            (1.0 - cip_s[2]) * 100.0
        );
        wp_savings_1.push(1.0 - wp_s[0]);
        cip_savings_1.push(1.0 - cip_s[0]);
        wp_savings_10.push(1.0 - wp_s[2]);
        cip_savings_10.push(1.0 - cip_s[2]);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "  => per-function beats whole-program by {:+.1} pp @1% and {:+.1} pp @10% (paper: +7/+13)",
        (mean(&cip_savings_1) - mean(&wp_savings_1)) * 100.0,
        (mean(&cip_savings_10) - mean(&wp_savings_10)) * 100.0
    );

    println!("\n[2/3] call-stack placement on radar (paper Fig. 9)");
    let eval = Evaluator::new(neat::bench_suite::by_name("radar").unwrap(), None);
    let cip = explore_rule(&eval, RuleKind::Cip, budget);
    let fcs = explore_rule(&eval, RuleKind::Fcs, budget);
    let cip_s = savings_at_thresholds(&cip.fpu_points(), &THRESHOLDS);
    let fcs_s = savings_at_thresholds(&fcs.fpu_points(), &THRESHOLDS);
    println!(
        "  CIP savings @1/5/10%: {:>5.1}% {:>5.1}% {:>5.1}%",
        (1.0 - cip_s[0]) * 100.0,
        (1.0 - cip_s[1]) * 100.0,
        (1.0 - cip_s[2]) * 100.0
    );
    println!(
        "  FCS savings @1/5/10%: {:>5.1}% {:>5.1}% {:>5.1}%",
        (1.0 - fcs_s[0]) * 100.0,
        (1.0 - fcs_s[1]) * 100.0,
        (1.0 - fcs_s[2]) * 100.0
    );

    println!("\n[3/3] CNN case study through the AOT artifact (JAX + Pallas → HLO → PJRT)");
    let paths = ArtifactPaths::default_location();
    if paths.all_present() {
        let runtime = LenetRuntime::load(&paths)?;
        let base = runtime.accuracy(&[24; 8], runtime.num_batches())?;
        println!(
            "  loaded artifact; full-precision accuracy {:.2}% over {} images",
            base * 100.0,
            runtime.num_batches() * runtime.batch
        );
        let problem = CnnProblem::new(&runtime, CnnRule::Pli, 1)?;
        let params = Nsga2Params { population: 12, generations: 6, ..Default::default() };
        Nsga2::new(params).run(&problem);
        let details = problem.take_details();
        let points: Vec<TradeoffPoint> =
            details.iter().map(|(_, d)| TradeoffPoint::new(d.error, d.nec)).collect();
        let s = savings_at_thresholds(&points, &THRESHOLDS);
        println!(
            "  per-layer search ({} configs): savings @1/5/10% loss = {:.1}% / {:.1}% / {:.1}%",
            details.len(),
            (1.0 - s[0]) * 100.0,
            (1.0 - s[1]) * 100.0,
            (1.0 - s[2]) * 100.0
        );
    } else {
        println!("  (skipped: run `make artifacts` to enable the CNN stage)");
    }

    println!("\ncompleted in {:.1?}", t_start.elapsed());
    Ok(())
}
