//! Heuristic tuning quickstart: instead of sweeping the whole Pareto
//! front (see `quickstart.rs`), ask the deployment question directly —
//! "how little energy can this program use while losing at most 1%
//! accuracy?" — with the constraint-driven bit-descent tuner.
//!
//!     cargo run --release --example heuristic_tuning

use neat::coordinator::{EvalProblem, Evaluator, Executor, RuleKind};
use neat::tuner::Tuner;

fn main() {
    // Steps 1-2: profile the workload; the CIP rule gives every hot
    // function its own mantissa width (one gene per function).
    let workload = neat::bench_suite::by_name("blackscholes").unwrap();
    let eval = Evaluator::new(workload, None);
    println!(
        "profiled: top functions = {:?} (target: {})",
        eval.top_functions,
        eval.target.name()
    );

    // The tuner talks to the same batched Problem the NSGA-II explorer
    // uses, so every probe wave fans over the executor's worker pool.
    let exec = Executor::default_parallel();
    let problem = EvalProblem::with_executor(&eval, RuleKind::Cip, exec);

    // One call: sensitivity-profile each function, start from the best
    // feasible uniform width, then binary-search each gene downward —
    // most error-insensitive function first — under a 1% error budget.
    let result = Tuner::error_budget(0.01).run(&problem);

    println!("\nsensitivity (most insensitive first):");
    for r in &result.sensitivity {
        println!(
            "  {:<16} {:.3e} error/bit",
            eval.top_functions[r.target], r.error_per_bit
        );
    }

    println!("\naccepted bit descents (one lattice wave per gene):");
    for s in &result.steps {
        println!(
            "  {:<16} {:>2} → {:>2} bits   err {:>6.3}%  NEC {:.4}",
            eval.top_functions[s.target],
            s.from,
            s.to,
            s.objectives.error * 100.0,
            s.objectives.energy
        );
    }

    // when single-gene lowering stalls in a local minimum, bounded
    // pairwise exchanges keep draining energy along iso-error ridges
    if !result.exchanges.is_empty() {
        println!("\naccepted exchange moves (lower ⇄ raise):");
        for x in &result.exchanges {
            println!(
                "  {:<16} {:>2} → {:>2}  ⇄  {:<16} {:>2} → {:>2}   err {:>6.3}%  NEC {:.4}",
                eval.top_functions[x.lowered],
                x.lowered_from,
                x.lowered_to,
                eval.top_functions[x.raised],
                x.raised_from,
                x.raised_to,
                x.objectives.error * 100.0,
                x.objectives.energy
            );
        }
    }

    println!(
        "\ntuned widths {:?} for {:?}",
        result.genome, eval.top_functions
    );
    println!(
        "error {:.3}%  →  {:.1}% FPU energy savings ({} probes of ≤400 in {} waves)",
        result.objectives.error * 100.0,
        (1.0 - result.objectives.energy) * 100.0,
        result.probes_used,
        result.waves
    );
}
