//! Per-layer CNN precision tuning (paper §V-H): drive the AOT-compiled
//! JAX/Pallas LeNet-5 through PJRT, searching per-layer mantissa widths
//! with NSGA-II. Requires `make artifacts`.
//!
//!     cargo run --release --example cnn_tuning

use neat::cnn::{CnnProblem, CnnRule};
use neat::explore::{Nsga2, Nsga2Params};
use neat::runtime::{ArtifactPaths, LenetRuntime, SLOT_NAMES};
use neat::stats::{lower_convex_hull, TradeoffPoint};

fn main() -> anyhow::Result<()> {
    let paths = ArtifactPaths::default_location();
    if !paths.all_present() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let runtime = LenetRuntime::load(&paths)?;
    println!(
        "loaded LeNet-5 artifact: batch={}, eval batches={}, trained baseline accuracy={:.2}%",
        runtime.batch,
        runtime.num_batches(),
        runtime.baseline_accuracy * 100.0
    );

    // small budget so the example finishes in ~a minute
    let problem = CnnProblem::new(&runtime, CnnRule::Pli, 1)?;
    let params = Nsga2Params { population: 10, generations: 5, ..Default::default() };
    Nsga2::new(params).run(&problem);
    let details = problem.take_details();
    println!("explored {} per-layer configurations", details.len());

    let points: Vec<TradeoffPoint> =
        details.iter().map(|(_, d)| TradeoffPoint::new(d.error, d.nec)).collect();
    let hull = lower_convex_hull(&points);
    println!("\nfrontier (accuracy loss vs modeled FPU energy):");
    println!("{:>10} {:>10}   per-slot mantissa bits", "loss", "NEC");
    for p in &hull {
        if let Some((bits, d)) = details
            .iter()
            .find(|(_, d)| d.error == p.error && d.nec == p.energy)
        {
            println!(
                "{:>9.2}% {:>10.4}   {:?} ({:?})",
                d.error * 100.0,
                d.nec,
                bits,
                SLOT_NAMES
            );
        }
    }
    Ok(())
}
