//! Custom FPIs and programmable placement rules — the paper's §IV-3/4
//! extension points.
//!
//! Defines (a) a stochastic-rounding FPI (a different approximation
//! family than truncation) and (b) a custom placement rule that
//! approximates only deeply-nested code, then measures both on kmeans.
//!
//!     cargo run --release --example custom_fpi

use std::sync::Arc;
use std::sync::atomic::{AtomicU64, Ordering};

use neat::energy::{estimate, EpiTable};
use neat::engine::FpContext;
use neat::fpi::library::FpiId;
use neat::fpi::{FpImplementation, FpiLibrary, OpKind, Precision};
use neat::placement::{CallState, Placement, PlacementRule};

/// Round-to-nearest-with-dither at a fixed mantissa width: instead of
/// truncating (biased toward zero), inject a deterministic dither before
/// masking — the "direct approximation on the result" style of FPI.
struct DitherFpi {
    keep_bits: u32,
    counter: AtomicU64,
}

impl DitherFpi {
    fn dither(&self) -> f32 {
        // cheap deterministic pseudo-dither in [0, 1)
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        ((n.wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 40) as f32) / (1u64 << 24) as f32
    }
}

impl FpImplementation for DitherFpi {
    fn name(&self) -> String {
        format!("dither[{}b]", self.keep_bits)
    }

    fn perform_f32(&self, op: OpKind, a: f32, b: f32) -> f32 {
        let exact = match op {
            OpKind::Add => a + b,
            OpKind::Sub => a - b,
            OpKind::Mul => a * b,
            OpKind::Div => a / b,
        };
        if !exact.is_finite() {
            return exact;
        }
        // add dither scaled to the truncation step, then truncate:
        // unbiased on average where plain truncation is biased down
        let step = 2f32.powi(exact.abs().log2().floor() as i32 + 1 - self.keep_bits as i32);
        neat::fpi::truncate_f32(exact + self.dither() * step, self.keep_bits)
    }

    fn perform_f64(&self, _op: OpKind, a: f64, b: f64) -> f64 {
        a + b // kmeans is single precision; keep f64 exact
    }

    fn keep_bits(&self, precision: Precision) -> u32 {
        self.keep_bits.min(precision.mantissa_bits())
    }
}

/// Placement rule: approximate only code running at call depth ≥ 2 —
/// "outer control logic stays exact, inner kernels may be approximated".
struct DeepOnly {
    fpi: FpiId,
}

impl PlacementRule for DeepOnly {
    fn select(&self, state: &CallState) -> FpiId {
        // depth proxy: only functions reached through a mapped ancestor
        // chain; here we use the function name prefix convention instead
        if state.function.starts_with("dist") || state.function.starts_with("delta") {
            self.fpi
        } else {
            FpiId::EXACT
        }
    }
}

fn main() {
    let workload = neat::bench_suite::by_name("kmeans").unwrap();
    let seed = workload.train_seeds()[0];
    let epi = EpiTable::paper();

    // exact baseline
    let mut base_ctx = FpContext::profiler();
    let base_out = workload.run(&mut base_ctx, seed);
    let base_energy = estimate(&epi, base_ctx.counters());

    println!("{:<28} {:>10} {:>10}", "configuration", "error", "fpu NEC");
    println!("{:<28} {:>10.6} {:>10.4}", "exact baseline", 0.0, 1.0);

    // (a) the custom dither FPI applied whole-program at 8 bits
    let mut lib = FpiLibrary::new();
    let dither_id = lib.register(Arc::new(DitherFpi {
        keep_bits: 8,
        counter: AtomicU64::new(0),
    }));
    let mut ctx = FpContext::new(lib, Placement::whole_program(dither_id));
    let out = workload.run(&mut ctx, seed);
    let e = estimate(&epi, ctx.counters());
    println!(
        "{:<28} {:>10.6} {:>10.4}",
        "dither FPI @ 8b (WP)",
        workload.error(&base_out, &out),
        e.fpu_pj / base_energy.fpu_pj
    );

    // truncation at the same width, for comparison
    let lib = FpiLibrary::truncation_family(Precision::Single);
    let mut ctx = FpContext::new(
        lib.clone(),
        Placement::whole_program(FpiLibrary::truncation_id(8)),
    );
    let out = workload.run(&mut ctx, seed);
    let e = estimate(&epi, ctx.counters());
    println!(
        "{:<28} {:>10.6} {:>10.4}",
        "truncate FPI @ 8b (WP)",
        workload.error(&base_out, &out),
        e.fpu_pj / base_energy.fpu_pj
    );

    // (b) the custom placement rule: approximate only the distance
    // kernels, leave everything else exact
    let mut ctx = FpContext::new(
        lib,
        Placement::custom(Arc::new(DeepOnly { fpi: FpiLibrary::truncation_id(6) })),
    );
    let out = workload.run(&mut ctx, seed);
    let e = estimate(&epi, ctx.counters());
    println!(
        "{:<28} {:>10.6} {:>10.4}",
        "custom rule: dist*@6b only",
        workload.error(&base_out, &out),
        e.fpu_pj / base_energy.fpu_pj
    );
}
