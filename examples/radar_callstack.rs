//! The function-call-stack experiment (paper §V-F / Fig. 9): on the
//! radar pipeline, the FCS rule prices the shared FFT kernel *by
//! caller* — one precision for fft-under-LPF, another for fft-under-PC
//! — which the CIP rule cannot express.
//!
//!     cargo run --release --example radar_callstack

use neat::coordinator::experiments::{explore_rule, Budget, THRESHOLDS};
use neat::coordinator::{Evaluator, RuleKind};
use neat::stats::savings_at_thresholds;

fn main() {
    let eval = Evaluator::new(neat::bench_suite::by_name("radar").unwrap(), None);
    println!(
        "radar: {} top functions; FCS maps {} (fft/complex_mul/twiddle follow their caller)",
        eval.top_functions.len(),
        eval.fcs_functions.len()
    );

    let budget = Budget::default();
    let cip = explore_rule(&eval, RuleKind::Cip, budget);
    let fcs = explore_rule(&eval, RuleKind::Fcs, budget);

    let cip_s = savings_at_thresholds(&cip.fpu_points(), &THRESHOLDS);
    let fcs_s = savings_at_thresholds(&fcs.fpu_points(), &THRESHOLDS);

    println!("\n{:<10} {:>12} {:>12} {:>12}", "rule", "@1% err", "@5% err", "@10% err");
    for (name, s) in [("CIP", &cip_s), ("FCS", &fcs_s)] {
        println!(
            "{name:<10} {:>11.1}% {:>11.1}% {:>11.1}%",
            (1.0 - s[0]) * 100.0,
            (1.0 - s[1]) * 100.0,
            (1.0 - s[2]) * 100.0
        );
    }
    println!(
        "\nFCS advantage: {:+.1} / {:+.1} / {:+.1} percentage points",
        (cip_s[0] - fcs_s[0]) * 100.0,
        (cip_s[1] - fcs_s[1]) * 100.0,
        (cip_s[2] - fcs_s[2]) * 100.0
    );

    println!("\nbest FCS configurations (per-caller-subtree widths):");
    for (genome, d) in fcs.front().iter().take(6) {
        println!(
            "  err {:>6.3}%  NEC {:>6.4}  {:?} -> {:?}",
            d.error * 100.0,
            d.fpu_nec,
            eval.fcs_functions,
            genome
        );
    }
}
