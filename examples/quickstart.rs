//! Quickstart: instrument a program, explore its precision tradeoff
//! space, and read the frontier — the paper's §IV workflow in ~60 lines
//! of user code.
//!
//!     cargo run --release --example quickstart

use neat::coordinator::experiments::{explore_rule, Budget, THRESHOLDS};
use neat::coordinator::{Evaluator, RuleKind};
use neat::report::ascii_tradeoff_plot;
use neat::stats::{lower_convex_hull, savings_at_thresholds};

fn main() {
    // Step 1-2: pick a workload; NEAT profiles it and fixes the
    // optimization target (blackscholes is single-precision).
    let workload = neat::bench_suite::by_name("blackscholes").unwrap();
    let eval = Evaluator::new(workload, None);
    println!(
        "profiled: top functions = {:?} (target: {})",
        eval.top_functions,
        eval.target.name()
    );

    // Step 3-5: the FPI library is mantissa truncation (24 widths); the
    // CIP placement rule maps each hot function to its own width; the
    // NSGA-II explorer searches the 24^4 configuration space.
    let result = explore_rule(&eval, RuleKind::Cip, Budget::default());

    // Step 6: analyze — the tradeoff scatter, its lower hull, and the
    // best configuration within each error budget.
    let points = result.fpu_points();
    let hull = lower_convex_hull(&points);
    println!(
        "{}",
        ascii_tradeoff_plot("blackscholes / CIP", &points, &hull, 56, 12)
    );

    let savings = savings_at_thresholds(&points, &THRESHOLDS);
    for (t, nec) in THRESHOLDS.iter().zip(&savings) {
        println!(
            "within {:>4.0}% error: {:>5.1}% FPU energy savings",
            t * 100.0,
            (1.0 - nec) * 100.0
        );
    }

    println!("\nPareto front (error, energy, per-function mantissa widths):");
    for (genome, d) in result.front().iter().take(8) {
        println!(
            "  err {:>6.3}%  NEC {:>6.4}  bits {:?} ({:?})",
            d.error * 100.0,
            d.fpu_nec,
            genome,
            eval.top_functions
        );
    }
}
